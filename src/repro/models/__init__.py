from . import attention, cnn, common, moe, ssm, transformer, xlstm
from .transformer import (cache_shapes, decode_step, forward, init_cache,
                          init_params, loss_fn, param_shapes, prefill)

__all__ = [
    "attention", "cache_shapes", "cnn", "common", "decode_step", "forward",
    "init_cache", "init_params", "loss_fn", "moe", "param_shapes", "prefill",
    "ssm", "transformer", "xlstm",
]
