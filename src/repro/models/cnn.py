"""LeNet and VGG-16 in pure JAX — the paper's own inference workloads.

Each model exposes per-layer apply functions so the UAV runtime can execute
a *placed* inference: layer j runs "on" node ``assign[j]`` (simulated), with
the intermediate activation shipped between placement units exactly as the
OULD objective prices it.  ``apply_layers(params, x, start, end)`` runs a
contiguous unit range — the execution primitive for placed inference and
for the shard_map pipeline.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .common import dense_init


def _conv(x, w, b, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


# ---------------------------------------------------------------------------
# LeNet — 7 placement units (matches core.profiles.lenet_profile)
# ---------------------------------------------------------------------------

def lenet_init(key, height: int = 326, width: int = 595, channels: int = 3,
               num_classes: int = 10) -> dict:
    ks = jax.random.split(key, 5)
    h, w = (height - 4) // 2, (width - 4) // 2
    h, w = (h - 4) // 2, (w - 4) // 2
    flat = h * w * 16
    return {
        "conv1": {"w": dense_init(ks[0], 75, (5, 5, channels, 6), jnp.float32),
                  "b": jnp.zeros((6,))},
        "conv2": {"w": dense_init(ks[1], 150, (5, 5, 6, 16), jnp.float32),
                  "b": jnp.zeros((16,))},
        "fc1": {"w": dense_init(ks[2], flat, (flat, 120), jnp.float32),
                "b": jnp.zeros((120,))},
        "fc2": {"w": dense_init(ks[3], 120, (120, 84), jnp.float32),
                "b": jnp.zeros((84,))},
        "fc3": {"w": dense_init(ks[4], 84, (84, num_classes), jnp.float32),
                "b": jnp.zeros((num_classes,))},
    }


def lenet_layers(params: dict) -> list[Callable]:
    return [
        lambda x: jax.nn.relu(_conv(x, params["conv1"]["w"],
                                    params["conv1"]["b"], padding="VALID")),
        lambda x: _pool(x),
        lambda x: jax.nn.relu(_conv(x, params["conv2"]["w"],
                                    params["conv2"]["b"], padding="VALID")),
        lambda x: _pool(x).reshape(x.shape[0], -1),
        lambda x: jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"]),
        lambda x: jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"]),
        lambda x: x @ params["fc3"]["w"] + params["fc3"]["b"],
    ]


# ---------------------------------------------------------------------------
# VGG-16 — 18 placement units (13 conv + 5 pool, head folded into unit 18)
# ---------------------------------------------------------------------------

_VGG_CFG = (64, 64, "p", 128, 128, "p", 256, 256, 256, "p",
            512, 512, 512, "p", 512, 512, 512, "p")


def vgg16_init(key, channels: int = 3, num_classes: int = 10) -> dict:
    params: dict = {}
    cin = channels
    ks = jax.random.split(key, 16)
    ki = 0
    for li, cfg in enumerate(_VGG_CFG):
        if cfg == "p":
            continue
        params[f"conv{li}"] = {
            "w": dense_init(ks[ki], 9 * cin, (3, 3, cin, cfg), jnp.float32),
            "b": jnp.zeros((cfg,))}
        cin = cfg
        ki += 1
    head_in = 7 * 7 * 512
    params["fc6"] = {"w": dense_init(ks[13], head_in, (head_in, 4096), jnp.float32),
                     "b": jnp.zeros((4096,))}
    params["fc7"] = {"w": dense_init(ks[14], 4096, (4096, 4096), jnp.float32),
                     "b": jnp.zeros((4096,))}
    params["fc8"] = {"w": dense_init(ks[15], 4096, (4096, num_classes), jnp.float32),
                     "b": jnp.zeros((num_classes,))}
    return params


def _vgg_head(params, x):
    # adaptive average pool to 7x7, then the 3 FC layers (folded unit)
    b, h, w, c = x.shape
    if h < 7 or w < 7:  # tiny test frames: zero-pad up to the pool grid
        x = jnp.pad(x, ((0, 0), (0, max(0, 7 - h)), (0, max(0, 7 - w)),
                        (0, 0)))
        h, w = max(h, 7), max(w, 7)
    hs, ws = h // 7, w // 7
    x = x[:, : hs * 7, : ws * 7]
    x = x.reshape(b, 7, hs, 7, ws, c).mean(axis=(2, 4))
    x = x.reshape(b, -1)
    x = jax.nn.relu(x @ params["fc6"]["w"] + params["fc6"]["b"])
    x = jax.nn.relu(x @ params["fc7"]["w"] + params["fc7"]["b"])
    return x @ params["fc8"]["w"] + params["fc8"]["b"]


def vgg16_layers(params: dict) -> list[Callable]:
    fns: list[Callable] = []
    for li, cfg in enumerate(_VGG_CFG):
        if cfg == "p":
            if li == len(_VGG_CFG) - 1:
                fns.append(lambda x: _vgg_head(params, _pool(x)))
            else:
                fns.append(lambda x: _pool(x))
        else:
            p = params[f"conv{li}"]
            fns.append(functools.partial(
                lambda x, p: jax.nn.relu(_conv(x, p["w"], p["b"])), p=p))
    return fns


def apply_layers(layer_fns: list[Callable], x: jax.Array,
                 start: int = 0, end: int | None = None) -> jax.Array:
    """Run units [start, end) — the placed-inference execution primitive."""
    end = end if end is not None else len(layer_fns)
    for fn in layer_fns[start:end]:
        x = fn(x)
    return x
