"""Shared building blocks: init helpers, RMSNorm, RoPE, SwiGLU MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, fan_in: int, shape: tuple[int, ...], dtype) -> jax.Array:
    scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    return ops.rmsnorm(x, scale, eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, D) with D even; positions: (S,) or
    broadcastable to x's S dim."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # expand to (..., S, 1, half) to broadcast over heads
    while cos.ndim < x.ndim - 1:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff if d_ff is not None else cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": dense_init(k1, d, (d, f), dt),
        "w_gate": dense_init(k2, d, (d, f), dt),
        "w_out": dense_init(k3, f, (f, d), dt),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    return h @ p["w_out"]
