"""Decoder LM assembly: pattern-grouped ``lax.scan`` over blocks.

Layers follow ``cfg.block_pattern`` repeated over depth (period p).  Blocks
are stored *stacked over groups* (G = n_layers / p) so the whole stack lowers
to one compact scan — essential for 512-device dry-run compile times — while
heterogeneous patterns (xlstm 7×mLSTM+1×sLSTM, hymba hybrid) stay exact:
the scan body executes the p pattern positions in order.

Block kinds
-----------
attn   : x + Attn(norm1(x));   x + FFN(norm2(x))     (FFN = MLP or MoE)
hybrid : x + ½(Attn + SSM)(norm1(x));  x + MLP(norm2(x))     (hymba)
mamba  : x + SSM(norm1(x))   [+ MLP if d_ff > 0]
mlstm  : x + mLSTM(norm1(x))                          (xLSTM, no FFN)
slstm  : x + sLSTM(norm1(x))
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import BlockKind, ModelConfig
from ..parallel.sharding import with_dp_constraint
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .common import dense_init, dtype_of, mlp_apply, mlp_init, rmsnorm


# ---------------------------------------------------------------------------
# per-block init / apply / decode dispatch
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: BlockKind) -> dict:
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": {"scale": jnp.ones((cfg.d_model,), dt)}}
    if kind == "attn":
        if cfg.attn == "mla":
            p["mla"] = attn_mod.mla_init(ks[0], cfg)
        else:
            p["attn"] = attn_mod.gqa_init(ks[0], cfg)
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), dt)}
        if cfg.moe is not None:
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg)
    elif kind == "hybrid":
        p["attn"] = attn_mod.gqa_init(ks[0], cfg)
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg)
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), dt)}
        p["mlp"] = mlp_init(ks[2], cfg)
    elif kind == "mamba":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg)
        if cfg.d_ff > 0:
            p["norm2"] = {"scale": jnp.ones((cfg.d_model,), dt)}
            p["mlp"] = mlp_init(ks[1], cfg)
    elif kind == "mlstm":
        p["mlstm"] = xlstm_mod.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = xlstm_mod.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _block_apply(p: dict, cfg: ModelConfig, kind: BlockKind, x: jax.Array,
                 positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Train/prefill-without-cache path.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        if cfg.attn == "mla":
            y, _ = attn_mod.mla_apply(p["mla"], cfg, h, positions)
        else:
            y, _ = attn_mod.gqa_apply(p["attn"], cfg, h, positions)
        x = x + y
        h2 = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y2, aux = moe_mod.moe_apply(p["moe"], cfg, h2)
        else:
            y2 = mlp_apply(p["mlp"], h2)
        x = x + y2
    elif kind == "hybrid":
        ya, _ = attn_mod.gqa_apply(p["attn"], cfg, h, positions)
        ys = ssm_mod.ssm_apply(p["ssm"], cfg, h)
        x = x + 0.5 * (ya + ys)
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps))
    elif kind == "mamba":
        x = x + ssm_mod.ssm_apply(p["ssm"], cfg, h)
        if cfg.d_ff > 0:
            x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps))
    elif kind == "mlstm":
        x = x + xlstm_mod.mlstm_apply(p["mlstm"], cfg, h)
    elif kind == "slstm":
        x = x + xlstm_mod.slstm_apply(p["slstm"], cfg, h)
    return with_dp_constraint(x), aux


def _block_decode(p: dict, cfg: ModelConfig, kind: BlockKind, x: jax.Array,
                  cache: Any, pos: jax.Array) -> tuple[jax.Array, Any]:
    """Single-token step with carried state.  Returns (x, new_cache)."""
    h = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        if cfg.attn == "mla":
            y, cache = attn_mod.mla_decode(p["mla"], cfg, h, cache, pos)
        else:
            kv = (cache["k"], cache["v"])
            y, (k, v) = attn_mod.gqa_decode(p["attn"], cfg, h, kv, pos)
            cache = {"k": k, "v": v}
        x = x + y
        h2 = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y2, _ = moe_mod.moe_apply(p["moe"], cfg, h2)
        else:
            y2 = mlp_apply(p["mlp"], h2)
        x = x + y2
    elif kind == "hybrid":
        kv = (cache["k"], cache["v"])
        ya, (k, v) = attn_mod.gqa_decode(p["attn"], cfg, h, kv, pos)
        ys, (cs, hs) = ssm_mod.ssm_decode(p["ssm"], cfg, h,
                                          (cache["conv"], cache["ssm"]), pos)
        cache = {"k": k, "v": v, "conv": cs, "ssm": hs}
        x = x + 0.5 * (ya + ys)
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps))
    elif kind == "mamba":
        y, (cs, hs) = ssm_mod.ssm_decode(p["ssm"], cfg, h,
                                         (cache["conv"], cache["ssm"]), pos)
        cache = {"conv": cs, "ssm": hs}
        x = x + y
        if cfg.d_ff > 0:
            x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps))
    elif kind == "mlstm":
        y, (cs, C, n, m) = xlstm_mod.mlstm_decode(
            p["mlstm"], cfg, h, (cache["conv"], cache["C"], cache["n"],
                                 cache["m"]), pos)
        cache = {"conv": cs, "C": C, "n": n, "m": m}
        x = x + y
    elif kind == "slstm":
        y, st = xlstm_mod.slstm_decode(
            p["slstm"], cfg, h, (cache["h"], cache["c"], cache["n"],
                                 cache["m"]), pos)
        cache = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
        x = x + y
    return with_dp_constraint(x), cache


def _block_cache_shapes(cfg: ModelConfig, kind: BlockKind, batch: int,
                        seq: int, dtype) -> dict:
    if kind == "attn":
        if cfg.attn == "mla":
            return attn_mod.mla_cache_shape(cfg, batch, seq, dtype)
        k, v = attn_mod.gqa_cache_shape(cfg, batch, seq, dtype)
        return {"k": k, "v": v}
    if kind == "hybrid":
        k, v = attn_mod.gqa_cache_shape(cfg, batch, seq, dtype)
        cs, hs = ssm_mod.ssm_cache_shape(cfg, batch, dtype)
        return {"k": k, "v": v, "conv": cs, "ssm": hs}
    if kind == "mamba":
        cs, hs = ssm_mod.ssm_cache_shape(cfg, batch, dtype)
        return {"conv": cs, "ssm": hs}
    if kind == "mlstm":
        cs, C, n, m = xlstm_mod.mlstm_cache_shape(cfg, batch, dtype)
        return {"conv": cs, "C": C, "n": n, "m": m}
    if kind == "slstm":
        h, c, n, m = xlstm_mod.slstm_cache_shape(cfg, batch, dtype)
        return {"h": h, "c": c, "n": n, "m": m}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model init / forward
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig) -> tuple[tuple[BlockKind, ...], int]:
    p = cfg.block_pattern
    assert cfg.n_layers % len(p) == 0, (cfg.name, cfg.n_layers, p)
    return p, cfg.n_layers // len(p)


# Dry-run probe knob (see kernels/chunked.py): unroll layer scans so XLA's
# cost model sees every group.  Never set during real execution.
UNROLL_SCANS = False


def _unroll(length: int) -> int:
    return length if UNROLL_SCANS else 1


def init_params(key, cfg: ModelConfig) -> dict:
    pat, groups = _pattern(cfg)
    dt = dtype_of(cfg.param_dtype)
    k_embed, k_head, *k_blocks = jax.random.split(key, 2 + len(pat) * groups)
    params: dict[str, Any] = {
        "embed": {"table": dense_init(k_embed, cfg.d_model,
                                      (cfg.vocab_padded, cfg.d_model), dt)},
        "final_norm": {"scale": jnp.ones((cfg.d_model,), dt)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model,
                                       (cfg.vocab_padded, cfg.d_model), dt).T
    blocks = []
    for pp, kind in enumerate(pat):
        per_group = [_block_init(k_blocks[g * len(pat) + pp], cfg, kind)
                     for g in range(groups)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
    params["blocks"] = blocks
    return params


def param_shapes(cfg: ModelConfig) -> Any:
    """Abstract parameter pytree (no allocation) — dry-run / checkpoints."""
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


def _embed_in(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    if "embeds" in batch:
        x = batch["embeds"].astype(dtype_of(cfg.compute_dtype))
    else:
        x = params["embed"]["table"][batch["tokens"]]
    return x.astype(dtype_of(cfg.compute_dtype))


def _lm_logits(params, cfg: ModelConfig, x: jax.Array,
               keep_padded: bool = False) -> jax.Array:
    """Logits over the padded vocab; pad columns masked to -inf.  The padded
    form keeps the head matmul + softmax sharded on the model axis (vocab may
    not divide it unpadded); callers slice only at API boundaries."""
    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = (x @ head).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab) * jnp.float32(-1e30)
        logits = logits + mask
    return logits if keep_padded else logits[..., : cfg.vocab]


def forward(params: dict, cfg: ModelConfig, batch: dict,
            remat: bool = False,
            keep_padded: bool = False) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits f32 (B,S,V), aux_loss)."""
    pat, groups = _pattern(cfg)
    x = _embed_in(params, cfg, batch)
    positions = jnp.arange(x.shape[1])

    def body(x, block_slices):
        aux = jnp.zeros((), jnp.float32)
        for pp, kind in enumerate(pat):
            x, a = _block_apply(block_slices[pp], cfg, kind, x, positions)
            aux = aux + a
        return x, aux

    if remat:
        body = jax.checkpoint(body)

    def scan_body(x, slices):
        x, aux = body(x, slices)
        return x, aux

    x, auxs = jax.lax.scan(scan_body, x, tuple(params["blocks"]),
                           unroll=_unroll(groups))
    return _lm_logits(params, cfg, x, keep_padded=keep_padded), auxs.sum()


def loss_fn(params: dict, cfg: ModelConfig, batch: dict,
            remat: bool = False) -> tuple[jax.Array, dict]:
    # padded logits keep the head matmul + softmax sharded on `model`
    from ..parallel.sharding import constrain
    logits, aux = forward(params, cfg, batch, remat=remat, keep_padded=True)
    logits = constrain(logits, ("data", None, "model"))
    labels = batch.get("labels")
    if labels is None:
        labels = batch["tokens"][:, 1:]
        logits = logits[:, :-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean() + 0.01 * aux
    return loss, {"loss": loss, "nll": nll.mean(), "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with stacked caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=None) -> list:
    """Concrete zero-initialized cache (m-states at -30 for stability)."""
    dtype = dtype or dtype_of(cfg.compute_dtype)
    shapes = cache_shapes(cfg, batch, seq, dtype)

    def make(path, s):
        fill = -30.0 if path and path[-1] == "m" else 0.0
        return jnp.full(s.shape, fill, s.dtype)

    return _tree_map_with_key(make, shapes)


def _tree_map_with_key(fn, tree, path=()):
    if isinstance(tree, dict):
        return {k: _tree_map_with_key(fn, v, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_tree_map_with_key(fn, v, path + (i,)) for i, v in enumerate(tree)]
        return type(tree)(t) if isinstance(tree, tuple) else t
    return fn(path, tree)


def cache_shapes(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> list:
    """Abstract cache pytree: list per pattern position, stacked over groups."""
    dtype = dtype or dtype_of(cfg.compute_dtype)
    pat, groups = _pattern(cfg)
    out = []
    for kind in pat:
        one = _block_cache_shapes(cfg, kind, batch, seq, dtype)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((groups, *s.shape), s.dtype), one)
        out.append(stacked)
    return out


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: list, pos: jax.Array) -> tuple[jax.Array, list]:
    """One new token per sequence.  tokens: (B, 1) int32; pos: scalar int32
    (current cache length).  Returns (logits (B, V) f32, new cache)."""
    pat, _ = _pattern(cfg)
    x = _embed_in(params, cfg, {"tokens": tokens})

    def scan_body(x, slices):
        block_slices, cache_slices = slices
        new_caches = []
        for pp, kind in enumerate(pat):
            x, c = _block_decode(block_slices[pp], cfg, kind, x,
                                 cache_slices[pp], pos)
            new_caches.append(c)
        return x, tuple(new_caches)

    _, groups = _pattern(cfg)
    x, new_cache = jax.lax.scan(scan_body, x,
                                (tuple(params["blocks"]), tuple(cache)),
                                unroll=_unroll(groups))
    logits = _lm_logits(params, cfg, x)
    return logits[:, 0], list(new_cache)


def prefill(params: dict, cfg: ModelConfig, batch: dict,
            max_len: int | None = None) -> tuple[jax.Array, list]:
    """Prefill: full-sequence forward that also emits the serving cache,
    padded to ``max_len`` slots (decode then appends in place)."""
    pat, groups = _pattern(cfg)
    x = _embed_in(params, cfg, batch)
    S = x.shape[1]
    max_len = max_len if max_len is not None else S
    positions = jnp.arange(S)

    def pad_kv(t):
        if t.shape[1] < max_len and not (cfg.attn == "swa" and cfg.window
                                         and t.shape[1] >= cfg.window):
            smax = (min(max_len, cfg.window) if cfg.attn == "swa" and cfg.window
                    else max_len)
            t = jnp.pad(t, [(0, 0), (0, smax - t.shape[1])] +
                        [(0, 0)] * (t.ndim - 2))
        return t

    def scan_body(x, block_slices):
        caches = []
        for pp, kind in enumerate(pat):
            p = block_slices[pp]
            h = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
            if kind in ("attn", "hybrid") and cfg.attn != "mla":
                key = "attn"
                y, (k, v) = attn_mod.gqa_apply(p[key], cfg, h, positions)
                if cfg.attn == "swa" and cfg.window and cfg.window < S:
                    # ring-buffer layout: slot = abs_pos % window
                    k = jnp.roll(k[:, -cfg.window:], S % cfg.window, axis=1)
                    v = jnp.roll(v[:, -cfg.window:], S % cfg.window, axis=1)
                c = {"k": pad_kv(k), "v": pad_kv(v)}
                if kind == "hybrid":
                    ys, (cs, hs) = ssm_mod.ssm_prefill(p["ssm"], cfg, h)
                    y = 0.5 * (y + ys)
                    c.update({"conv": cs, "ssm": hs})
                x = x + y
                x = x + _ffn(p, cfg, x)
            elif kind == "attn":  # mla
                y, latent = attn_mod.mla_apply(p["mla"], cfg, h, positions)
                c = pad_kv(latent)
                x = x + y
                x = x + _ffn(p, cfg, x)
            elif kind == "mamba":
                y, (cs, hs) = ssm_mod.ssm_prefill(p["ssm"], cfg, h)
                c = {"conv": cs, "ssm": hs}
                x = x + y
                if cfg.d_ff > 0:
                    x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"]["scale"],
                                                        cfg.norm_eps))
            elif kind == "mlstm":
                y, c = xlstm_mod.mlstm_prefill(p["mlstm"], cfg, h)
                x = x + y
            elif kind == "slstm":
                y, st = xlstm_mod._slstm_core(p["slstm"], cfg, h, None)
                c = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
                x = x + y
            x = with_dp_constraint(x)
            caches.append(c)
        return x, tuple(caches)

    x, cache = jax.lax.scan(scan_body, x, tuple(params["blocks"]),
                            unroll=_unroll(groups))
    logits = _lm_logits(params, cfg, x[:, -1:])
    return logits[:, 0], list(cache)


def _ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h2 = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
    if cfg.moe is not None:
        y2, _ = moe_mod.moe_apply(p["moe"], cfg, h2)
        return y2
    return mlp_apply(p["mlp"], h2)
