"""Mamba-2-style selective SSM block (used by hymba's parallel-SSM head and
as the generic ``mamba`` block kind).

Per block: in-projections → short causal depthwise conv → SiLU → selective
scan (chunked SSD, Pallas on TPU) → gated RMSNorm → out-projection.
Decode carries (conv_state, ssm_state) instead of a KV cache — O(1) memory
per step, which is what makes ``long_500k`` runnable for SSM/hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import dense_init, dtype_of, rmsnorm


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = max(1, d_inner // 64)          # P = 64 per SSM head
    p = d_inner // n_heads
    return d_inner, n_heads, p, s.d_state


def ssm_init(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    d_inner, nh, p, n = _dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, (d, d_inner), dt),
        "w_z": dense_init(ks[1], d, (d, d_inner), dt),
        "w_bc": dense_init(ks[2], d, (d, 2 * nh * n), dt),
        "w_dt": dense_init(ks[3], d, (d, nh), dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv": dense_init(ks[4], s.conv_kernel, (s.conv_kernel, d_inner), dt),
        "norm": {"scale": jnp.ones((d_inner,), dt)},
        "w_out": dense_init(ks[5], d_inner, (d_inner, d), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time.  x: (B, S, D); w: (K, D).
    state: (B, K-1, D) trailing context (decode).  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y, new_state


def _ssm_core(p: dict, cfg: ModelConfig, x: jax.Array,
              conv_state: jax.Array | None, ssm_state: jax.Array | None
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared by train (states None) and decode (states carried)."""
    B, S, d = x.shape
    d_inner, nh, ph, n = _dims(cfg)
    xs = x @ p["w_x"]
    z = x @ p["w_z"]
    xs, conv_state_new = _causal_conv(xs, p["conv"], conv_state)
    xs = jax.nn.silu(xs)

    bc = (x @ p["w_bc"]).reshape(B, S, nh, 2 * n)
    b, c = jnp.split(bc, 2, axis=-1)
    dt_ = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                          + p["dt_bias"])                     # (B, S, nh)
    a = jnp.exp(-dt_ * jnp.exp(p["A_log"]))                   # decay ∈ (0,1)
    xh = xs.reshape(B, S, nh, ph)
    b = b * dt_[..., None]                                    # dt-weighted input
    y, ssm_state_new = ops.ssd_scan(xh, a, b, c, h0=ssm_state,
                                    chunk=cfg.ssm.chunk)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_out"], conv_state_new, ssm_state_new


def ssm_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    y, _, _ = _ssm_core(p, cfg, x, None, None)
    return y


def ssm_prefill(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, tuple]:
    """Returns (y, (conv_state, ssm_state)) so decode can continue."""
    y, cs, hs = _ssm_core(p, cfg, x, None, None)
    return y, (cs, hs)


def ssm_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: tuple,
               pos: jax.Array) -> tuple[jax.Array, tuple]:
    conv_state, ssm_state = cache
    y, cs, hs = _ssm_core(p, cfg, x, conv_state, ssm_state)
    return y, (cs, hs)


def ssm_cache_shape(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, nh, ph, n = _dims(cfg)
    return (jax.ShapeDtypeStruct((batch, s.conv_kernel - 1, d_inner), dtype),
            jax.ShapeDtypeStruct((batch, nh, ph, n), jnp.float32))
