"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, strictly recurrent) — arXiv:2405.04517, adapted per DESIGN.md.

xlstm-1.3b uses a 7:1 mLSTM:sLSTM pattern (period 8), d_ff = 0 (the blocks
embed their own up/down projections, no separate FFN).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops, ref
from .common import dense_init, dtype_of, rmsnorm


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = 2 * cfg.d_model
    nh = cfg.n_heads
    return d_inner, nh, d_inner // nh


def mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nh, p = _mlstm_dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "w_up": dense_init(ks[0], d, (d, 2 * d_inner), dt),      # [x | z]
        "conv": dense_init(ks[1], 4, (4, d_inner), dt),
        "w_qkv": dense_init(ks[2], d_inner, (d_inner, 3 * d_inner), dt),
        "w_gates": dense_init(ks[3], d_inner, (d_inner, 2 * nh), dt),
        "gate_bias": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]
                                     ).astype(jnp.float32),      # [i | f]
        "norm": {"scale": jnp.ones((d_inner,), dt)},
        "w_out": dense_init(ks[4], d_inner, (d_inner, d), dt),
    }


def _mlstm_core(p, cfg, x, cache):
    from .ssm import _causal_conv
    B, S, d = x.shape
    d_inner, nh, ph = _mlstm_dims(cfg)
    conv_state = cache[0] if cache is not None else None
    up = x @ p["w_up"]
    xs, z = jnp.split(up, 2, axis=-1)
    xc, conv_state_new = _causal_conv(xs, p["conv"], conv_state)
    xc = jax.nn.silu(xc)
    qkv = xc @ p["w_qkv"]
    q, k, v = (t.reshape(B, S, nh, ph) for t in jnp.split(qkv, 3, -1))
    gates = (xc @ p["w_gates"]).astype(jnp.float32) + p["gate_bias"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)                 # (B,S,nh)
    if cache is None:
        y, _ = ops.mlstm_scan(q, k, v, i_gate, f_gate, chunk=cfg.ssm.chunk
                              if cfg.ssm else 256)
        state_new = None
    else:
        _, C, n, m = cache
        y, (C, n, m) = ref.mlstm_scan(q, k, v, i_gate, f_gate, C, n, m)
        state_new = (C, n, m)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_out"], conv_state_new, state_new


def mlstm_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    y, _, _ = _mlstm_core(p, cfg, x, None)
    return y


def mlstm_prefill(p: dict, cfg: ModelConfig, x: jax.Array
                  ) -> tuple[jax.Array, dict]:
    """Chunked forward + state handover for decode continuation.

    The chunked cell returns (C, n) scaled by exp(−m_global) with m_global
    the sequence-max input gate — the same invariant (state = true·exp(−m))
    the sequential ref maintains with its running max, so decode can carry
    on directly after transposing C to the ref (k-dim, v-dim) layout."""
    from .ssm import _causal_conv
    B, S, d = x.shape
    d_inner, nh, ph = _mlstm_dims(cfg)
    up = x @ p["w_up"]
    xs, z = jnp.split(up, 2, axis=-1)
    xc, conv_state = _causal_conv(xs, p["conv"], None)
    xc = jax.nn.silu(xc)
    qkv = xc @ p["w_qkv"]
    q, k, v = (t.reshape(B, S, nh, ph) for t in jnp.split(qkv, 3, -1))
    gates = (xc @ p["w_gates"]).astype(jnp.float32) + p["gate_bias"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)
    y, (C, n, m) = ops.mlstm_scan(q, k, v, i_gate, f_gate,
                                  chunk=cfg.ssm.chunk if cfg.ssm else 256)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps) * jax.nn.silu(z)
    cache = {"conv": conv_state, "C": jnp.swapaxes(C, -1, -2),
             "n": n, "m": m}
    return y @ p["w_out"], cache


def mlstm_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: tuple,
                 pos: jax.Array) -> tuple[jax.Array, tuple]:
    y, cs, (C, n, m) = _mlstm_core(p, cfg, x, cache)
    return y, (cs, C, n, m)


def mlstm_cache_shape(cfg: ModelConfig, batch: int, dtype):
    d_inner, nh, p = _mlstm_dims(cfg)
    return (jax.ShapeDtypeStruct((batch, 3, d_inner), dtype),          # conv
            jax.ShapeDtypeStruct((batch, nh, p, p), jnp.float32),      # C
            jax.ShapeDtypeStruct((batch, nh, p), jnp.float32),         # n
            jax.ShapeDtypeStruct((batch, nh), jnp.float32))            # m


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory cell with exponential gating (sequential over time)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    p = d // nh
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "w": dense_init(ks[0], d, (d, 4 * d), dt),       # i f z o pre-acts
        "r": dense_init(ks[1], p, (nh, p, 4 * p), dt),   # block-diag recurrent
        "bias": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                                 jnp.zeros((2 * d,))]).astype(jnp.float32),
        "norm": {"scale": jnp.ones((d,), dt)},
        "w_out": dense_init(ks[2], d, (d, d), dt),
    }


def _slstm_step(p, cfg, carry, wx_t):
    """One timestep.  carry: (h, c, n, m) each (B, nh, ph) f32.
    wx_t: (B, 4d) input pre-activations for this step."""
    h, c, n, m = carry
    B = h.shape[0]
    nh, ph = h.shape[1], h.shape[2]
    rh = jnp.einsum("bhp,hpq->bhq", h.astype(p["r"].dtype), p["r"])   # (B,nh,4ph)
    pre = wx_t.reshape(B, nh, 4 * ph).astype(jnp.float32) + rh.astype(jnp.float32)
    i_, f_, z_, o_ = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(logf + m, i_)
    i_act = jnp.exp(i_ - m_new)
    f_act = jnp.exp(logf + m - m_new)
    c_new = f_act * c + i_act * jnp.tanh(z_)
    n_new = f_act * n + i_act
    h_new = jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def _slstm_core(p, cfg, x, state):
    B, S, d = x.shape
    nh = cfg.n_heads
    ph = d // nh
    wx = (x @ p["w"]).astype(jnp.float32) + p["bias"]                 # (B,S,4d)
    if state is None:
        z = jnp.zeros((B, nh, ph), jnp.float32)
        state = (z, z, z, jnp.full((B, nh, ph), -1e30, jnp.float32))

    def step(carry, wx_t):
        new = _slstm_step(p, cfg, carry, wx_t)
        return new, new[0]

    state_new, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(y, p["norm"]["scale"], cfg.norm_eps)
    return y @ p["w_out"], state_new


def slstm_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    y, _ = _slstm_core(p, cfg, x, None)
    return y


def slstm_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: tuple,
                 pos: jax.Array) -> tuple[jax.Array, tuple]:
    y, state = _slstm_core(p, cfg, x, cache)
    return y, state


def slstm_cache_shape(cfg: ModelConfig, batch: int, dtype):
    nh = cfg.n_heads
    ph = cfg.d_model // nh
    s = jax.ShapeDtypeStruct((batch, nh, ph), jnp.float32)
    return (s, s, s, s)
