"""Mixture-of-Experts FFN with top-k routing.

Two implementations behind ``cfg.moe.impl``:

* ``scatter`` (production): sort-based grouped matmul.  Tokens are argsorted
  by expert id, packed into per-expert capacity buffers with a scatter-add,
  batched through the expert SwiGLU with ``ecd,edf->ecf`` einsums (E on the
  ``model`` mesh axis = expert parallelism), and combined back with the gate
  weights.  Compute is O(tokens·top_k·capacity_factor) — FLOPs-honest for the
  roofline (a dense O(E) formulation would inflate HLO_FLOPs ~E/top_k×).
  Over-capacity tokens are dropped (standard Switch semantics).

* ``einsum`` (tiny configs / ablation): dense "run every expert on every
  token, mask by gate" — exact top-k semantics, no drops, O(E) compute.
  Used by smoke tests (exactness) and as a perf-pass ablation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init, dtype_of


def moe_init(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    assert m is not None
    d, f, e = cfg.d_model, cfg.d_ff, m.num_experts
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, (d, e), jnp.float32),
        "w_in": dense_init(ks[1], d, (e, d, f), dt),
        "w_gate": dense_init(ks[2], d, (e, d, f), dt),
        "w_out": dense_init(ks[3], f, (e, f, d), dt),
    }


def _route(p: dict, cfg: ModelConfig, x2: jax.Array):
    """x2: (T, d) → gates (T, K) softmax-normalized over chosen experts,
    idx (T, K) int32, plus the router aux loss (load balancing)."""
    m = cfg.moe
    logits = (x2.astype(jnp.float32) @ p["router"])          # (T, E)
    topv, topi = jax.lax.top_k(logits, m.top_k)
    gates = jax.nn.softmax(topv, axis=-1)
    # Switch-style load-balance aux loss: E · Σ_e f_e · p_e
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(topi[:, 0], m.num_experts, dtype=jnp.float32)
    aux = m.num_experts * jnp.mean(probs.mean(0) * onehot.mean(0))
    return gates, topi, aux


def _expert_ffn(p: dict, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) → (E, C, d) SwiGLU per expert."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _moe_scatter(p: dict, cfg: ModelConfig, x2: jax.Array):
    m = cfg.moe
    T, d = x2.shape
    E, K = m.num_experts, m.top_k
    gates, topi, aux = _route(p, cfg, x2)
    cap = max(1, int(T * K * m.capacity_factor / E))

    flat_e = topi.reshape(T * K)                       # expert of each slot
    flat_g = gates.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)           # group slots by expert
    sorted_e = flat_e[order]
    sorted_t = order // K                              # source token of slot
    # position of each slot within its expert queue
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[sorted_e]
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    from ..parallel.sharding import active_mesh, constrain
    xe = jnp.zeros((E, cap, d), x2.dtype)
    src = x2[sorted_t] * keep[:, None].astype(x2.dtype)
    xe = xe.at[sorted_e, pos_c].add(src, mode="drop")
    # Expert-parallel when E divides the model axis; otherwise shard the
    # capacity dim over (data × model) so expert compute never replicates
    # (e.g. granite's E=40 on a 16-way model axis).
    mesh, axes = active_mesh()
    ep_ok = mesh is not None and E % mesh.shape[axes.model] == 0
    buf_spec = ("model", "data", None) if ep_ok else (None, "data_model", None)
    xe = constrain(xe, buf_spec)
    ye = _expert_ffn(p, xe)                            # (E, cap, d)
    ye = constrain(ye, buf_spec)
    out_slot = ye[sorted_e, pos_c] * (flat_g[order] * keep)[:, None].astype(x2.dtype)
    y = jnp.zeros_like(x2).at[sorted_t].add(out_slot, mode="drop")
    return y, aux


def _moe_einsum(p: dict, cfg: ModelConfig, x2: jax.Array):
    m = cfg.moe
    gates, topi, aux = _route(p, cfg, x2)
    # combine (T, E): summed gate per expert (handles duplicate picks)
    comb = jnp.zeros((x2.shape[0], m.num_experts), jnp.float32)
    comb = comb.at[jnp.arange(x2.shape[0])[:, None], topi].add(gates)
    ye = _expert_ffn(p, jnp.broadcast_to(x2[None], (m.num_experts, *x2.shape)))
    y = jnp.einsum("te,etd->td", comb.astype(x2.dtype), ye)
    return y, aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (§Perf iteration — explicit collectives).
#
# GSPMD lowers the scatter into the model-sharded (E, C, d) buffer as
# full-buffer cross-replica reductions (measured ~60× the minimum traffic on
# llama4).  The explicit formulation exploits that tokens are replicated
# across the `model` axis under DP×TP: each model column packs ONLY its own
# experts' tokens locally (no dispatch communication at all), runs its expert
# shard, and one psum over `model` combines the outputs — the minimum
# possible: one (T_loc, d) all-reduce per MoE layer.
# ---------------------------------------------------------------------------

SHARD_MAP_MIN_TOKENS = 16_384  # below this, GSPMD token-movement wins


def _moe_shard_map(p: dict, cfg: ModelConfig, x2: jax.Array, mesh, axes):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E, K = m.num_experts, m.top_k
    msize = mesh.shape[axes.model]
    # Pad the expert dim up to the TP axis (e.g. granite's 40 → 48): dead
    # experts hold zero weights and never win routing; the ~E_pad/E extra
    # matmul work is far cheaper than GSPMD's buffer reductions (§Perf).
    E_pad = (E + msize - 1) // msize * msize
    epp = E_pad // msize
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]
    dsize = 1
    for a in axes.dp:
        dsize *= mesh.shape[a]
    T, d = x2.shape
    t_loc = T // dsize
    cap = max(1, int(t_loc * K * m.capacity_factor / E))
    if E_pad != E:
        def padw(w):
            return jnp.pad(w, ((0, E_pad - E), (0, 0), (0, 0)))
        p = {**p, "w_gate": padw(p["w_gate"]), "w_in": padw(p["w_in"]),
             "w_out": padw(p["w_out"])}

    def local(router, w_gate, w_in, w_out, x_loc):
        col = jax.lax.axis_index(axes.model)
        gates, topi, aux = _route({"router": router}, cfg, x_loc)
        flat_e = topi.reshape(t_loc * K)
        flat_g = gates.reshape(t_loc * K)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        sorted_t = order // K
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_loc * K) - starts[sorted_e]
        mine = (sorted_e // epp) == col
        keep = (pos < cap) & mine
        e_loc = jnp.where(mine, sorted_e - col * epp, 0)
        pos_c = jnp.minimum(pos, cap - 1)

        # FSDP gather of this column's expert shard
        wg = jax.lax.all_gather(w_gate, axes.dp, axis=1, tiled=True)
        wi = jax.lax.all_gather(w_in, axes.dp, axis=1, tiled=True)
        wo = jax.lax.all_gather(w_out, axes.dp, axis=2, tiled=True)

        xe = jnp.zeros((epp, cap, d), x_loc.dtype)
        src = x_loc[sorted_t] * keep[:, None].astype(x_loc.dtype)
        xe = xe.at[e_loc, pos_c].add(src, mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wi)
        ye = jnp.einsum("ecf,efd->ecd", h, wo)
        out_slot = ye[e_loc, pos_c] * (flat_g[order] * keep)[:, None].astype(
            x_loc.dtype)
        y_partial = jnp.zeros_like(x_loc).at[sorted_t].add(out_slot,
                                                           mode="drop")
        y = jax.lax.psum(y_partial, axes.model)   # combine expert columns
        aux = jax.lax.pmean(aux, axes.dp)
        return y, aux

    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axes.model, dp, None), P(axes.model, dp, None),
                  P(axes.model, None, dp), P(dp, None)),
        out_specs=(P(dp, None), P()),
        check_rep=False,
    )(p["router"], p["w_gate"], p["w_in"], p["w_out"], x2)
    return y, aux


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss)."""
    from ..parallel.sharding import active_mesh
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    mesh, axes = active_mesh()
    if (cfg.moe.impl == "shard_map" and mesh is not None):
        dsize = 1
        for a in axes.dp:
            dsize *= mesh.shape[a]
        # At decode-scale token counts the FSDP weight gather inside the
        # shard_map dominates (§Perf: llama4 decode regressed 3×); GSPMD
        # scatter moves tokens instead, which is right for tiny T.
        if (B * S) % dsize == 0 and (B * S) >= SHARD_MAP_MIN_TOKENS:
            y, aux = _moe_shard_map(p, cfg, x2, mesh, axes)
            return y.reshape(B, S, d), aux
    fn = _moe_scatter if cfg.moe.impl in ("scatter", "shard_map") \
        else _moe_einsum
    y, aux = fn(p, cfg, x2)
    return y.reshape(B, S, d), aux
