"""Attention variants: GQA (w/ optional sliding window) and MLA.

Each variant exposes ``init(key, cfg) -> params``, ``apply(params, cfg, x,
positions) -> (y, kv)`` for train/prefill, and ``decode(params, cfg, x,
cache, pos) -> (y, new_cache_entry)`` for single-token serving.

KV caches (per layer):
  gqa/swa: (B, Smax, n_kv, hd) k and v — SWA uses Smax = window (ring buffer).
  mla:     (B, Smax, kv_lora + rope_dim) latent (the MLA decode-memory win).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import dense_init, dtype_of, rmsnorm, rope


# ---------------------------------------------------------------------------
# GQA (covers full attention and sliding-window via cfg.window)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "wqkv": dense_init(k1, d, (d, (hq + 2 * hkv) * hd), dt),
        "wo": dense_init(k2, hq * hd, (hq * hd, d), dt),
    }


def _split_qkv(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    qkv = x @ p["wqkv"]
    q, k, v = jnp.split(qkv, [hq * hd, (hq + hkv) * hd], axis=-1)
    return (q.reshape(B, S, hq, hd), k.reshape(B, S, hkv, hd),
            v.reshape(B, S, hkv, hd))


def gqa_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array) -> tuple[jax.Array, tuple]:
    """Full-sequence attention (train / prefill).  Returns (y, (k, v))."""
    B, S, _ = x.shape
    q, k, v = _split_qkv(p, cfg, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    window = cfg.window if cfg.attn == "swa" else None
    y = ops.attention(q, k, v, causal=True, window=window)
    y = y.reshape(B, S, cfg.n_heads * cfg.hd)
    return y @ p["wo"], (k, v)


def gqa_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: tuple,
               pos: jax.Array) -> tuple[jax.Array, tuple]:
    """x: (B, 1, d); cache: (k, v) each (B, Smax, hkv, hd); pos: scalar."""
    B = x.shape[0]
    k_cache, v_cache = cache
    smax = k_cache.shape[1]
    q, k, v = _split_qkv(p, cfg, x)
    q = rope(q, pos[None], cfg.rope_theta)[:, 0]          # (B, hq, hd)
    k = rope(k, pos[None], cfg.rope_theta)
    slot = pos % smax if cfg.attn == "swa" else pos       # ring buffer for SWA
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, 1)
    length = jnp.minimum(pos + 1, smax)
    window = cfg.window if cfg.attn == "swa" else None
    y = ops.decode_attention(q, k_cache, v_cache, length, window=window)
    y = y.reshape(B, 1, cfg.n_heads * cfg.hd)
    return y @ p["wo"], (k_cache, v_cache)


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq: int,
                    dtype) -> tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
    smax = min(seq, cfg.window) if cfg.attn == "swa" and cfg.window else seq
    s = jax.ShapeDtypeStruct((batch, smax, cfg.n_kv, cfg.hd), dtype)
    return (s, s)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    assert m is not None
    d, hq = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, (d, m.q_lora_rank), dt),
        "wq_b": dense_init(ks[1], m.q_lora_rank, (m.q_lora_rank, hq * qk), dt),
        "wkv_a": dense_init(ks[2], d, (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            (m.kv_lora_rank, hq * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": dense_init(ks[4], hq * m.v_head_dim, (hq * m.v_head_dim, d), dt),
        "norm_q": {"scale": jnp.ones((m.q_lora_rank,), dt)},
        "norm_kv": {"scale": jnp.ones((m.kv_lora_rank,), dt)},
    }


def _mla_qkv(p, cfg: ModelConfig, x, positions):
    """Materialized q, k, v for full-sequence attention + the latent cache."""
    m = cfg.mla
    B, S, _ = x.shape
    hq = cfg.n_heads
    q = rmsnorm(x @ p["wq_a"], p["norm_q"]["scale"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, hq, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["norm_kv"]["scale"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    latent = jnp.concatenate([c_kv, k_rope[:, :, 0]], axis=-1)

    kvb = (c_kv @ p["wkv_b"]).reshape(B, S, hq, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, hq, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q, k, v, latent


def mla_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    m = cfg.mla
    B, S, _ = x.shape
    q, k, v, latent = _mla_qkv(p, cfg, x, positions)
    y = ops.attention(q, k, v, causal=True,
                      scale=(m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    y = y.reshape(B, S, cfg.n_heads * m.v_head_dim)
    return y @ p["wo"], latent


def mla_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: jax.Array,
               pos: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Latent-cache decode: cache (B, Smax, kv_lora + rope) stores the
    compressed KV; k/v are re-expanded from the latent each step (the MLA
    memory/compute trade)."""
    m = cfg.mla
    B = x.shape[0]
    q, k, v, latent = _mla_qkv(p, cfg, x, pos[None])
    cache = jax.lax.dynamic_update_slice_in_dim(cache, latent.astype(cache.dtype), pos, 1)
    c_kv, k_rope = jnp.split(cache, [m.kv_lora_rank], axis=-1)
    kvb = (c_kv @ p["wkv_b"]).reshape(B, cache.shape[1], cfg.n_heads,
                                      m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v_all = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    k_all = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], m.qk_rope_head_dim))], -1)
    y = ops.decode_attention(
        q[:, 0], k_all, v_all, pos + 1,
        scale=(m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    y = y.reshape(B, 1, cfg.n_heads * m.v_head_dim)
    return y @ p["wo"], cache


def mla_cache_shape(cfg: ModelConfig, batch: int, seq: int, dtype):
    m = cfg.mla
    return jax.ShapeDtypeStruct((batch, seq, m.kv_lora_rank + m.qk_rope_head_dim),
                                dtype)
