"""Serving launcher: batched greedy generation with the production server
(prefill + donated-cache decode), reduced config on CPU — plus request
placement over the serving pool via any registered planner.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --steps 16 \\
        --planner ould-dp --pool-nodes 8
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1p8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--planner", default="ould-dp",
                    help="registered placement strategy for the pool "
                         "(see repro.core.available_planners())")
    ap.add_argument("--pool-nodes", type=int, default=8)
    ap.add_argument("--sparse-k", type=int, default=None,
                    help="candidate budget for the *-sparse planners "
                         "(default: ceil(sqrt(pool nodes)))")
    ap.add_argument("--execute", action="store_true",
                    help="run a placed CNN inference through the repro.exec "
                         "engine and report predicted vs measured latency "
                         "(plus a calibrated re-solve)")
    ap.add_argument("--transport", default="inproc",
                    choices=("inproc", "loopback", "multiproc"),
                    help="byte-moving backend for --execute transfers: "
                         "inproc = modeled delay (default), loopback = "
                         "worker OS processes over sockets, multiproc = one "
                         "JAX process per node group; non-inproc backends "
                         "also calibrate the rates from realized bandwidth "
                         "before the re-solve")
    ap.add_argument("--transport-workers", type=int, default=2)
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory "
                         "(repro.exec.compile_cache): repeat runs and "
                         "rejoining nodes warm from disk")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto-loadable trace of this "
                         "run (repro.obs): solver/admission spans for the "
                         "pool placement, engine stage walls and transport "
                         "shipments under --execute")
    args = ap.parse_args()

    tracer = metrics = None
    if args.trace_out:
        from repro.obs import MetricsRegistry, Tracer
        tracer = Tracer()
        metrics = MetricsRegistry()

    import jax
    import numpy as np

    import repro.configs as C
    from repro.core.radio import TpuLinkModel
    from repro.models import init_params
    from repro.runtime.serve import ServeConfig, Server, schedule_requests

    cfg = C.get_config(args.arch).reduced(n_layers=2, d_model=128, vocab=1024)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.steps + 1, batch_size=args.batch))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)
    out = srv.generate(prompts, steps=args.steps)
    print(f"[serve] arch={args.arch} generated {out.shape}: {out[0].tolist()}")

    # Place the batch's requests over a simulated pool with the chosen
    # planner — provenance comes from the Plan, not a hard-coded label.
    link = TpuLinkModel()
    n = args.pool_nodes
    coords = np.stack([np.arange(n) % link.torus[0],
                       np.arange(n) // link.torus[0]], -1)
    rates_bits = link.rate_matrix(coords, np.zeros(n, np.int64)) * 8.0
    plan, ev = schedule_requests(
        C.get_config(args.arch), n_nodes=n, requests=args.batch,
        hbm_bytes=16e9 * 16, flops_budget=197e12 * 10,
        rates_bits=rates_bits, planner=args.planner,
        sparse_k=args.sparse_k)
    sparse = ""
    if plan.solve_stats is not None and plan.solve_stats.k:
        st = plan.solve_stats
        sparse = (f" sparse[k={st.k} pruned={st.pruned_fraction:.2f} "
                  f"dense_fallbacks={st.n_dense_fallback}]")
    print(f"[serve] placement planner={plan.planner_name} "
          f"view={plan.view_kind} status={plan.status} "
          f"admitted={plan.n_admitted}/{args.batch} "
          f"comm={ev.comm_latency_s * 1e6:.1f}us "
          f"stages(req0)={len(plan.stages(0)) if plan.admitted[0] else 0}"
          + sparse)

    if args.execute:
        # Plan-faithful execution: place the paper's CNN over the same pool
        # with the same planner, run it through the exec engine (transfers
        # routed through the chosen transport backend), then re-solve on the
        # measured-calibrated profile — and, with a byte-moving transport,
        # on realized link bandwidth too (DESIGN.md §5/§7).
        from repro.core import (Problem, SnapshotView, get_planner,
                                lenet_profile)
        from repro.exec import (ExecutionEngine, calibrated_problem,
                                compile_cache, compile_plan, layer_fns_for)
        from repro.transport import make_transport

        if args.compile_cache:
            compile_cache.enable(args.compile_cache)
        profile = lenet_profile()
        rng = np.random.default_rng(0)
        # Hotspot the frames on two camera nodes: lenet wants ~108 MB end to
        # end, so at 128 MB/node the co-sourced requests must offload part of
        # their path — the plan has transfers for the transport to carry.
        sources = (np.arange(args.batch) % min(2, n)).astype(np.int64)
        prob = Problem(profile, np.full(n, 128e6), np.full(n, 95e9),
                       rates_bits, sources, compute_speed=np.full(n, 9.5e9))
        if tracer is not None:
            # Route placement through the controller so the trace carries
            # the solver span + per-request admission verdicts.
            from repro.runtime.serve import AdmissionController
            cnn_plan = AdmissionController(
                args.planner, tracer=tracer, sparse_k=args.sparse_k).admit(
                prob, SnapshotView(rates_bits),
                request_ids=list(range(args.batch)))
        else:
            cnn_plan = get_planner(args.planner, sparse_k=args.sparse_k).plan(
                prob, SnapshotView(rates_bits))
        graph = compile_plan(cnn_plan)
        transport = make_transport(args.transport,
                                   n_workers=args.transport_workers)
        engine = ExecutionEngine(layer_fns_for(profile), transport=transport,
                                 tracer=tracer)
        frames = rng.standard_normal(
            (args.batch, 326, 595, 3)).astype(np.float32)
        try:
            if tracer is not None:
                from repro.exec.stage_graph import trace_args
                from repro.obs import ENGINE
                t_round = tracer.now()
            report = engine.run(graph, frames,
                                predicted_s=cnn_plan.evaluate().per_request_s)
            if tracer is not None:
                tracer.span(ENGINE, "execute_round", t_round,
                            tracer.now() - t_round, args=trace_args(graph))
            moving = args.transport != "inproc"
            cal_prob, recon = calibrated_problem(
                prob, report, transport=transport if moving else None)
            replan = get_planner(args.planner, sparse_k=args.sparse_k).plan(
                cal_prob, SnapshotView(cal_prob.rates))
            regraph = compile_plan(replan)
            if tracer is not None:
                t_round = tracer.now()
            rereport = engine.run(regraph, frames,
                                  predicted_s=replan.evaluate().per_request_s)
            if tracer is not None:
                tracer.span(ENGINE, "execute_recal", t_round,
                            tracer.now() - t_round, args=trace_args(regraph))
        finally:
            transport.close()
        mae0 = report.abs_error_s[list(report.outputs)].mean()
        mae1 = rereport.abs_error_s[list(rereport.outputs)].mean()
        print(f"[exec] tasks={len(graph.tasks)} shared={graph.n_shared} "
              f"transfers={len(graph.transfers)} "
              f"executed_avg={report.executed_s[list(report.outputs)].mean():.4f}s")
        print(f"[exec] {recon.summary()}")
        if args.transport != "inproc":
            bw = ", ".join(
                f"{s}->{d}: {ls.bytes_per_s / 1e6:.0f} MB/s"
                for (s, d), ls in sorted(transport.link_stats.items()))
            print(f"[exec] transport={args.transport} "
                  f"workers={sorted(set(transport.worker_pids))} "
                  f"moved={transport.moved_bytes / 1e6:.1f}MB ({bw})")
            print(f"[exec] re-solve priced comm from "
                  f"{replan.problem.comm_source!r}")
        print(f"[exec] predicted-vs-measured MAE {mae0 * 1e3:.2f}ms -> "
              f"{mae1 * 1e3:.2f}ms after calibrated re-solve")
        if metrics is not None:
            metrics.counter("exec.tasks").inc(len(graph.tasks))
            metrics.counter("exec.transfers").inc(len(graph.transfers))
            metrics.counter("exec.admitted").inc(int(cnn_plan.n_admitted))
            metrics.gauge("exec.executed_avg_s").set(
                float(report.executed_s[list(report.outputs)].mean()))
            metrics.gauge("exec.mae_s").set(float(mae0))
            metrics.gauge("exec.mae_recal_s").set(float(mae1))
            for (s, d), ls in sorted(transport.link_stats.items()):
                metrics.gauge(f"transport.link.{s}-{d}.bytes_per_s").set(
                    ls.bytes_per_s)

    if tracer is not None:
        n_ev = tracer.export_chrome(args.trace_out)
        print(f"[trace] wrote {n_ev} events to {args.trace_out} "
              f"(n_dropped={tracer.n_dropped}) — load in ui.perfetto.dev")
        if metrics is not None and metrics.names():
            snap = metrics.snapshot()
            print("[trace] metrics: " + ", ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in snap.items() if not isinstance(v, dict)))


if __name__ == "__main__":
    main()
