"""Serving launcher: batched greedy generation with the production server
(prefill + donated-cache decode), reduced config on CPU.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --steps 16
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1p8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np

    import repro.configs as C
    from repro.models import init_params
    from repro.runtime.serve import ServeConfig, Server

    cfg = C.get_config(args.arch).reduced(n_layers=2, d_model=128, vocab=1024)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.steps + 1, batch_size=args.batch))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)
    out = srv.generate(prompts, steps=args.steps)
    print(f"[serve] arch={args.arch} generated {out.shape}: {out[0].tolist()}")


if __name__ == "__main__":
    main()
