"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init)."""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from ..parallel.sharding import MeshAxes


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            "launch/dryrun.py (it forces 512 host devices) or on a real pod")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def mesh_axes(mesh: Mesh) -> MeshAxes:
    if "pod" in mesh.axis_names:
        return MeshAxes(data=("pod", "data"), model="model")
    return MeshAxes(data=("data",), model="model")


def chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
