"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be imported/executed before any other jax usage: the first two lines
force 512 host platform devices so the production meshes can be built.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Per cell it records: memory_analysis, cost_analysis (FLOPs/bytes),
per-collective traffic parsed from the post-SPMD HLO, lower/compile wall
times — into benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json,
which §Roofline and the perf loop read.
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from .. import configs as C                            # noqa: E402
from ..configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: E402
from ..data.pipeline import DataConfig, batch_specs    # noqa: E402
from ..models import transformer                       # noqa: E402
from ..parallel import sharding as sh                  # noqa: E402
from ..runtime import steps                            # noqa: E402
from .mesh import chips, make_production_mesh, mesh_axes  # noqa: E402

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

# long_500k applicability: sub-quadratic archs only (DESIGN.md §5)
def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "SKIP(long_500k): pure full-attention arch (O(L^2) KV)"
    return True, ""


def production_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype="bfloat16",
                               compute_dtype="bfloat16")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the step function of this shape kind."""
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                      global_batch=shape.global_batch,
                      embed_stub_dim=cfg.d_model if cfg.embed_stub else None)
    params = transformer.param_shapes(cfg)
    if shape.kind == "train":
        tcfg = steps.TrainConfig()
        opt = jax.eval_shape(lambda p: steps.init_opt_state(p, tcfg), params)
        return {"params": params, "opt_state": opt,
                "batch": batch_specs(dcfg, jnp.bfloat16)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(dcfg, jnp.bfloat16)}
    # decode: one new token against a cache of seq_len
    cache = transformer.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    return {"params": params,
            "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------

def _named(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cache, mesh, axes: sh.MeshAxes):
    dsize = 1
    for a in axes.dp:
        dsize *= mesh.shape[a]
    msize = mesh.shape[axes.model]
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def spec(s):
        dims = s.shape
        out = [None] * len(dims)
        if len(dims) > 1 and dims[1] % dsize == 0:
            out[1] = dp
        for i in range(2, len(dims)):
            if dims[i] % msize == 0:
                out[i] = axes.model
                break
        return P(*out)

    return jax.tree.map(spec, cache)


def shardings_for(cfg: ModelConfig, shape: ShapeConfig, mesh, specs: dict):
    axes = mesh_axes(mesh)
    pspec = sh.param_pspecs(specs["params"], mesh, axes)
    pshard = _named(mesh, pspec)
    dsize = 1
    for a in axes.dp:
        dsize *= mesh.shape[a]
    bdiv = shape.global_batch % dsize == 0
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def bspec(s):
        out = [None] * len(s.shape)
        if bdiv:
            out[0] = dp
        if s.ndim == 3 and s.shape[-1] == cfg.d_model:  # embed-stub inputs
            pass
        return NamedSharding(mesh, P(*out))

    if shape.kind == "train":
        oshard = {
            "m": pshard, "v": pshard,
            "step": NamedSharding(mesh, P()),
        }
        if "comp_error" in specs["opt_state"]:
            oshard["comp_error"] = pshard
        bshard = jax.tree.map(bspec, specs["batch"])
        return {"params": pshard, "opt_state": oshard, "batch": bshard}
    if shape.kind == "prefill":
        return {"params": pshard,
                "batch": jax.tree.map(bspec, specs["batch"])}
    cshard = _named(mesh, cache_pspecs(specs["cache"], mesh, axes))
    return {"params": pshard,
            "tokens": NamedSharding(mesh, P(dp if bdiv else None, None)),
            "cache": cshard,
            "pos": NamedSharding(mesh, P())}


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\]\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

# per-chip link-traffic weight per result byte (ring algorithms, n≫1)
_TRAFFIC_W = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Parse post-SPMD HLO; returns per-collective result bytes and the
    weighted per-chip link traffic (documented in DESIGN.md §8)."""
    per_op: dict[str, float] = {}
    traffic = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        b = size * _DTYPE_BYTES.get(dtype, 4)
        per_op[op] = per_op.get(op, 0.0) + b
        traffic += _TRAFFIC_W[op] * b
    per_op["weighted_link_traffic"] = traffic
    per_op["count"] = len(_COLL_RE.findall(hlo_text))
    return per_op


# ---------------------------------------------------------------------------
# cost probes: XLA counts a while-loop body ONCE, so the full-model compile
# under-reports scan flops.  We lower 1-group and 2-group variants with all
# scans unrolled and solve  cost(G) = E + G·B  exactly (E = embed/head/opt,
# B = per-group cost).  The full compile still proves shardability + memory.
# ---------------------------------------------------------------------------

def _probe_cost(cfg: ModelConfig, shape: ShapeConfig, mesh,
                train_cfg=None) -> dict:
    from ..kernels import chunked
    from ..models import transformer as tr
    period = len(cfg.block_pattern)
    out: dict = {}
    tr.UNROLL_SCANS = True
    chunked.UNROLL_SCANS = True
    try:
        costs = []
        for groups in (1, 2):
            pcfg = dataclasses.replace(cfg, n_layers=groups * period)
            specs = input_specs(pcfg, shape)
            shards = shardings_for(pcfg, shape, mesh, specs)
            if shape.kind == "train":
                fn = steps.make_train_step(
                    pcfg, train_cfg if train_cfg is not None else steps.TrainConfig())
                jitted = jax.jit(fn, in_shardings=(shards["params"],
                                                   shards["opt_state"],
                                                   shards["batch"]),
                                 donate_argnums=(0, 1))
                a = (specs["params"], specs["opt_state"], specs["batch"])
            elif shape.kind == "prefill":
                fn = steps.make_prefill_step(pcfg)
                jitted = jax.jit(fn, in_shardings=(shards["params"],
                                                   shards["batch"]))
                a = (specs["params"], specs["batch"])
            else:
                fn = steps.make_decode_step(pcfg)
                jitted = jax.jit(fn, in_shardings=(shards["params"],
                                                   shards["tokens"],
                                                   shards["cache"],
                                                   shards["pos"]),
                                 donate_argnums=(2,))
                a = (specs["params"], specs["tokens"], specs["cache"],
                     specs["pos"])
            compiled = jitted.lower(*a).compile()
            c = compiled.cost_analysis()
            c = c[0] if isinstance(c, (list, tuple)) else c
            coll = collective_bytes(compiled.as_text())
            costs.append({
                "flops": float(c.get("flops", 0.0)),
                "bytes": float(c.get("bytes accessed", 0.0)),
                "coll": coll["weighted_link_traffic"],
            })
        f1, f2 = costs
        G = cfg.n_layers // period
        for key in ("flops", "bytes", "coll"):
            B = f2[key] - f1[key]
            E = 2 * f1[key] - f2[key]
            out[f"derived_{key}_per_partition"] = E + G * B
            out[f"probe_{key}_fixed"] = E
            out[f"probe_{key}_per_group"] = B
    finally:
        tr.UNROLL_SCANS = False
        chunked.UNROLL_SCANS = False
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, verbose: bool = True,
             cfg_transform=None, tag: str = "",
             train_cfg: "steps.TrainConfig | None" = None) -> dict:
    """``cfg_transform``: optional ModelConfig→ModelConfig hook — the perf
    loop's knob (chunk sizes, capacity factors, …).  ``tag`` suffixes the
    artifact name so optimized variants never overwrite the paper-faithful
    baseline artifacts."""
    cfg = production_cfg(C.get_config(arch))
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    mesh_name = ("multi" if multi_pod else "single") + (f"__{tag}" if tag else "")
    ok, why = cell_supported(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(mesh)
    sh.set_active_mesh(mesh, axes)
    try:
        specs = input_specs(cfg, shape)
        shards = shardings_for(cfg, shape, mesh, specs)

        tcfg = train_cfg if train_cfg is not None else steps.TrainConfig()
        if shape.kind == "train":
            fn = steps.make_train_step(cfg, tcfg)
            jitted = jax.jit(
                fn,
                in_shardings=(shards["params"], shards["opt_state"],
                              shards["batch"]),
                out_shardings=(shards["params"], shards["opt_state"], None),
                donate_argnums=(0, 1))
            args = (specs["params"], specs["opt_state"], specs["batch"])
        elif shape.kind == "prefill":
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(shards["params"],
                                               shards["batch"]))
            args = (specs["params"], specs["batch"])
        else:
            fn = steps.make_decode_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(shards["params"], shards["tokens"],
                              shards["cache"], shards["pos"]),
                out_shardings=(None, shards["cache"]),
                donate_argnums=(2,))
            args = (specs["params"], specs["tokens"], specs["cache"],
                    specs["pos"])

        t0 = time.perf_counter()
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        mem_rec = {k: getattr(mem, k) for k in dir(mem)
                   if k.endswith("bytes") or k.endswith("_in_bytes")
                   and not k.startswith("_")}
        if "peak_memory_in_bytes" not in mem_rec:
            # older jaxlib memory_analysis lacks the peak field; derive it
            # (aliased/donated argument bytes are not held twice)
            mem_rec["peak_memory_in_bytes"] = (
                mem_rec.get("argument_size_in_bytes", 0)
                + mem_rec.get("output_size_in_bytes", 0)
                + mem_rec.get("temp_size_in_bytes", 0)
                - mem_rec.get("alias_size_in_bytes", 0))
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        coll = collective_bytes(compiled.as_text())

        probe = _probe_cost(cfg, shape, mesh, train_cfg=train_cfg)
        rec.update({
            "status": "ok",
            "chips": chips(mesh),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem_rec,
            "flops_per_partition": float(cost.get("flops", -1.0)),
            "bytes_per_partition": float(cost.get("bytes accessed", -1.0)),
            "collectives": coll,
            **probe,
        })
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {mesh_name}: "
                  f"compile={t_compile:.1f}s flops/part={rec['flops_per_partition']:.3e} "
                  f"coll={coll['weighted_link_traffic']:.3e}B")
            print(f"[dryrun]   memory_analysis: {mem_rec}")
    except Exception as e:  # noqa: BLE001 — record failures as data
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {mesh_name}: FAILED {e}")
    finally:
        sh.set_active_mesh(None)
    if save:
        _save(rec)
    return rec


def _save(rec: dict) -> None:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    p = ART_DIR / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    p.write_text(json.dumps(rec, indent=1, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list(C.ARCH_IDS) if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if not (args.all or (args.arch and args.shape)):
        ap.error("pass --all or both --arch and --shape")

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = ("multi" if mp else "single") + \
                    (f"__{args.tag}" if args.tag else "")
                out = ART_DIR / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and out.exists():
                    prev = json.loads(out.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, shape, mp, tag=args.tag)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
