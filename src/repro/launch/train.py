"""Training launcher.

Single-host CPU (default): runs the fault-tolerant loop on a reduced config.
Production: `--dryrun` lowers the full config on the production mesh (see
dryrun.py for the full sweep); on a real TPU pod the same code path runs with
jax.distributed initialized by the cluster scheduler.

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --steps 50
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1p8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced config (CPU-sized)")
    args = ap.parse_args()

    import repro.configs as C
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.runtime import TrainConfig, train_loop

    cfg = C.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=2, d_model=128, vocab=1024)
    tcfg = TrainConfig(grad_compression=args.grad_compression,
                       optimizer=AdamWConfig(total_steps=args.steps))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch,
                      embed_stub_dim=cfg.d_model if cfg.embed_stub else None)
    lcfg = train_loop.LoopConfig(total_steps=args.steps,
                                 ckpt_every=max(args.steps // 4, 1),
                                 ckpt_dir=args.ckpt_dir)
    out = train_loop.run_with_restarts(cfg, tcfg, lcfg, dcfg)
    print(f"[train] arch={args.arch} steps={out['last_step'] + 1} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"stragglers={out['straggler_events']}")


if __name__ == "__main__":
    main()
