"""End-to-end observability for the serving path (`repro.obs`) — DESIGN §9.

Two pieces, both designed around the "reconstruct from kernel outputs,
never instrument inside jit" rule:

* :mod:`repro.obs.tracer` — a bounded flight-recorder :class:`Tracer`
  (numpy struct-of-arrays ring buffer, span/instant events, vectorized
  batch appends) exporting Chrome trace-event JSON loadable in Perfetto;
  :class:`NullTracer` is the default, so the traced-off path is free.
* :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` of counters,
  gauges, and fixed-bucket histograms behind one ``snapshot() -> dict``,
  subsuming the ad-hoc per-layer telemetry (simulator counters,
  ``ResolveStats`` aggregation, queue tallies, transport bandwidth).
"""

from .metrics import (LATENCY_EDGES_S, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .tracer import (ADMISSION, ENGINE, FRAMES, NULL_TRACER, QUEUE, SOLVER,
                     TRANSPORT, NullTracer, Tracer)

__all__ = [
    "ADMISSION", "ENGINE", "FRAMES", "NULL_TRACER", "QUEUE", "SOLVER",
    "TRANSPORT", "Counter", "Gauge", "Histogram", "LATENCY_EDGES_S",
    "MetricsRegistry", "NullTracer", "Tracer",
]
