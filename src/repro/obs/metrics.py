"""MetricsRegistry: counters, gauges, and fixed-bucket histograms behind
one ``snapshot() -> dict`` (DESIGN.md §9).

Before this module every layer kept its own private telemetry — the
simulator's bare ``served``/``missed``/``outages`` ints, the solver's
``ResolveStats``, ``NodeQueues``' enqueue/drop tallies, the transport's
per-link byte counts.  The registry is the one place those land: a
subsystem creates named instruments once at wiring time and bumps them with
plain attribute math (no locks, no label cartesian products — one process,
one run), and ``snapshot()`` flattens everything into the dict that
``SimResult.metrics``, ``bench_swarm``, and ``launch/serve.py`` report.

Instruments are deliberately minimal:

* :class:`Counter` — monotone float/int accumulator (``inc``);
* :class:`Gauge`  — last-write-wins scalar (``set``);
* :class:`Histogram` — fixed bucket edges declared at creation
  (vectorized ``observe_many`` for per-window latency arrays; counts +
  sum + min/max, so percentile estimates stay bounded-memory).
"""

from __future__ import annotations

import numpy as np


class Counter:
    """Monotone accumulator."""

    __slots__ = ("n",)

    def __init__(self):
        self.n = 0

    def inc(self, v=1) -> None:
        self.n += v

    @property
    def value(self):
        return self.n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("v",)

    def __init__(self):
        self.v = 0.0

    def set(self, v) -> None:
        self.v = v

    @property
    def value(self):
        return self.v


class Histogram:
    """Fixed-bucket histogram: ``edges`` are the upper bounds of each
    bucket (an implicit +inf bucket catches the rest).  ``observe_many``
    is one ``np.searchsorted`` + ``np.bincount`` over a window's samples —
    the per-tick latency path stays vectorized."""

    __slots__ = ("edges", "counts", "total", "sum", "min", "max")

    def __init__(self, edges):
        self.edges = np.asarray(edges, float)
        if self.edges.ndim != 1 or self.edges.size == 0:
            raise ValueError("histogram needs a 1-D, non-empty edge array")
        if np.any(np.diff(self.edges) <= 0):
            raise ValueError("histogram edges must be strictly increasing")
        self.counts = np.zeros(self.edges.size + 1, np.int64)
        self.total = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, x: float) -> None:
        self.observe_many(np.asarray([x], float))

    def observe_many(self, xs: np.ndarray) -> None:
        xs = np.asarray(xs, float)
        if xs.size == 0:
            return
        idx = np.searchsorted(self.edges, xs, side="left")
        self.counts += np.bincount(idx, minlength=self.counts.size)
        self.total += int(xs.size)
        self.sum += float(xs.sum())
        self.min = min(self.min, float(xs.min()))
        self.max = max(self.max, float(xs.max()))

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper edge of the bucket holding the
        q-th sample; +inf when it lands in the overflow bucket)."""
        if self.total == 0:
            return float("inf")
        rank = q * (self.total - 1)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank, side="right"))
        return float(self.edges[b]) if b < self.edges.size else float("inf")

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else float("nan")

    @property
    def value(self) -> dict:
        return {"count": self.total, "sum": self.sum,
                "min": self.min if self.total else float("nan"),
                "max": self.max if self.total else float("nan"),
                "edges": self.edges.tolist(),
                "counts": self.counts.tolist()}


class MetricsRegistry:
    """Named instruments, created on first use, snapshotted as one dict.

    Names are dotted paths (``queue.dropped``, ``transport.moved_bytes``);
    re-requesting a name returns the same instrument, re-requesting it as a
    different kind raises — one meaning per name per run.
    """

    def __init__(self):
        self._instruments: dict[str, object] = {}

    def _get(self, name: str, kind, *args):
        inst = self._instruments.get(name)
        if inst is None:
            inst = kind(*args)
            self._instruments[name] = inst
        elif type(inst) is not kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(inst).__name__}, not {kind.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, edges=None) -> Histogram:
        if name not in self._instruments and edges is None:
            raise ValueError(f"histogram {name!r} needs edges on creation")
        return self._get(name, Histogram, edges)

    def names(self) -> list[str]:
        return sorted(self._instruments)

    def snapshot(self) -> dict:
        """Every instrument's current value, keyed by name — counters and
        gauges as scalars, histograms as their full bucket dicts."""
        return {name: inst.value
                for name, inst in sorted(self._instruments.items())}


# Default latency bucket edges (seconds): log-ish ladder from 1 ms to the
# multi-minute waits a saturated queue produces under sustained overload.
LATENCY_EDGES_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                   1000.0)
