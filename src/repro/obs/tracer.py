"""Structured frame tracing: a bounded flight-recorder ring buffer with
Chrome trace-event export (DESIGN.md §9).

The serving path's whole objective is the latency between data collection
and decision-making, yet scenario-level aggregates (p50/p99/p999, miss
decomposition) cannot say *where inside one frame's life* the time went —
queue wait vs re-solve stall vs stage wall vs transfer.  The tracer is that
causal layer: subsystems emit **spans** (an interval with a duration) and
**instants** (a point event) onto named tracks, and the recorder keeps the
most recent ``capacity`` events in numpy struct-of-arrays — no per-event
Python object allocation on the hot path, vectorized batch appends for the
per-frame reconstruction, and a hard memory bound no matter how long the
scenario runs (older events are overwritten, counted in ``n_dropped``).

Two contracts keep the overhead honest:

* **The default is off.**  :class:`NullTracer` implements the same API as
  no-ops; every traced call site guards bulk argument preparation with
  ``tracer.enabled``, so the traced-off serving path is bit-identical to
  the pre-tracing code and costs ~one attribute check per window.
* **Reconstruct from kernel outputs, never instrument inside jit.**  The
  vectorized queue advance, the jitted DP dispatch, and the stage closures
  are never modified to emit events mid-kernel; callers rebuild each
  frame's spans *post hoc* from the arrays those kernels already return
  (Lindley start/finish, ``ResolveStats``, measured stage walls).  Tracing
  therefore cannot perturb the numbers it reports.

``export_chrome(path)`` writes the Chrome trace-event JSON array format —
loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing`` —
with one *process* per track (admission / solver / queue / engine /
transport / frames) and one *thread* per lane (node id), so a swarm's
per-node queues render as parallel timelines.
"""

from __future__ import annotations

import json
import math
import time

import numpy as np

# Pre-registered subsystem tracks (Chrome pid).  New subsystems register
# theirs via ``Tracer.track(name)`` — codes are allocated in call order.
ADMISSION = 0
SOLVER = 1
QUEUE = 2
ENGINE = 3
TRANSPORT = 4
FRAMES = 5

_BUILTIN_TRACKS = ("admission", "solver", "queue", "engine", "transport",
                   "frames")

# Sentinel duration marking an instant event in the ring buffer.
_INSTANT = -1.0


class Tracer:
    """Bounded structured event recorder (one instance == one trace).

    Events live in parallel numpy arrays of fixed ``capacity``; appends
    wrap around (flight recorder: the *latest* events survive).  Columns:

    ========  =======================================================
    ``ts``    event start, seconds (caller's time domain — simulated
              seconds in the swarm runtime, wall seconds in the CLI)
    ``dur``   span duration in seconds; ``-1`` marks an instant
    ``name``  interned name id (:meth:`intern`)
    ``track`` subsystem code (:meth:`track`)
    ``lane``  sub-track within the subsystem — node id, or 0
    ``frame`` stream/request id the event belongs to, or ``-1``
    ``a0/a1`` two numeric argument slots; labels are registered per
              name via :meth:`intern` (e.g. ``wait_s``/``service_s``)
    ========  =======================================================

    Rich (dict) arguments are allowed on *low-rate* events only (epoch
    solver spans, CLI placements): they are kept in a side dict keyed by
    absolute sequence number and dropped when their ring slot is
    overwritten.  Per-frame events must use the numeric slots.
    """

    enabled = True

    def __init__(self, capacity: int = 1 << 17):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        # np.full (not zeros) throughout: calloc'd pages would fault lazily
        # on first append, charging the recorder's memory cost to the hot
        # path instead of to construction.
        self._ts = np.full(capacity, 0.0)
        self._dur = np.full(capacity, 0.0)
        self._name = np.full(capacity, 0, np.int32)
        self._track = np.full(capacity, 0, np.int16)
        self._lane = np.full(capacity, 0, np.int32)
        self._frame = np.full(capacity, -1, np.int64)
        self._a0 = np.full(capacity, np.nan)
        self._a1 = np.full(capacity, np.nan)
        self.seq = 0                       # events ever appended
        self._names: list[str] = []
        self._name_ids: dict[str, int] = {}
        self._arg_labels: dict[int, tuple[str, str]] = {}
        self._tracks: list[str] = list(_BUILTIN_TRACKS)
        self._track_ids = {t: i for i, t in enumerate(self._tracks)}
        self._rich: dict[int, dict] = {}   # abs seq -> args dict (low-rate)
        self._t0 = time.perf_counter()     # origin of the real-time clock

    # -- clock --------------------------------------------------------------
    def now(self) -> float:
        """Wall seconds since this tracer was created — the real-time
        domain for engine/CLI spans (simulated runs pass sim time instead;
        the two domains never share a trace, see DESIGN.md §9)."""
        return time.perf_counter() - self._t0

    # -- registration -------------------------------------------------------
    def track(self, name: str) -> int:
        """Track code for ``name``, registering a new subsystem track on
        first use (this is how a new subsystem joins the trace)."""
        code = self._track_ids.get(name)
        if code is None:
            code = len(self._tracks)
            self._tracks.append(name)
            self._track_ids[name] = code
        return code

    def intern(self, name: str, a0_label: str = "a0",
               a1_label: str = "a1") -> int:
        """Intern an event name; the labels name the numeric arg slots in
        the exported trace.  Idempotent — call once at wiring time and keep
        the id, or pass the string to emit APIs (interned on the fly)."""
        nid = self._name_ids.get(name)
        if nid is None:
            nid = len(self._names)
            self._names.append(name)
            self._name_ids[name] = nid
            self._arg_labels[nid] = (a0_label, a1_label)
        return nid

    def _nid(self, name) -> int:
        return name if isinstance(name, int) else self.intern(name)

    # -- scalar emit --------------------------------------------------------
    def span(self, track: int, name, ts: float, dur: float, *,
             lane: int = 0, frame: int = -1, a0: float = math.nan,
             a1: float = math.nan, args: dict | None = None) -> None:
        """One interval event (Chrome complete event, phase ``X``)."""
        i = self.seq % self.capacity
        self._ts[i] = ts
        self._dur[i] = dur
        self._name[i] = self._nid(name)
        self._track[i] = track
        self._lane[i] = lane
        self._frame[i] = frame
        self._a0[i] = a0
        self._a1[i] = a1
        if args is not None:
            self._rich[self.seq] = args
        self.seq += 1

    def instant(self, track: int, name, ts: float, *, lane: int = 0,
                frame: int = -1, a0: float = math.nan, a1: float = math.nan,
                args: dict | None = None) -> None:
        """One point event (Chrome instant event, phase ``i``)."""
        self.span(track, name, ts, _INSTANT, lane=lane, frame=frame,
                  a0=a0, a1=a1, args=args)

    # -- vectorized emit ----------------------------------------------------
    def _append_batch(self, track: int, nid: int, ts, dur, lane, frame,
                      a0, a1) -> None:
        n = ts.shape[0]
        cap = self.capacity

        def _cut(v, sl):
            return v[sl] if isinstance(v, np.ndarray) else v

        if n >= cap:                    # keep the newest `capacity` events
            sl = slice(n - cap, n)
            ts = ts[sl]
            dur, lane = _cut(dur, sl), _cut(lane, sl)
            frame, a0, a1 = _cut(frame, sl), _cut(a0, sl), _cut(a1, sl)
            self.seq += n - cap
            n = cap
        start = self.seq % cap
        end = start + n
        cols = ((self._ts, ts), (self._dur, dur), (self._lane, lane),
                (self._frame, frame), (self._a0, a0), (self._a1, a1))
        if end <= cap:                  # hot path: one contiguous write
            d = slice(start, end)
            self._name[d] = nid
            self._track[d] = track
            for col, src in cols:
                col[d] = src
        else:                           # ring wrap: two writes
            k = cap - start
            for d, s in ((slice(start, cap), slice(0, k)),
                         (slice(0, end - cap), slice(k, n))):
                self._name[d] = nid
                self._track[d] = track
                for col, src in cols:
                    col[d] = _cut(src, s)
        self.seq += n

    def span_batch(self, track: int, name, ts: np.ndarray, dur, *,
                   lane=0, frame=-1, a0=math.nan, a1=math.nan) -> None:
        """Vectorized span append — the per-frame reconstruction path.

        ``ts`` is a (n,) float array; ``dur``/``lane``/``frame``/``a0``/
        ``a1`` are scalars or aligned (n,) arrays.  Scalars are written as
        slice fills (never materialized per event); one numpy slice write
        per call (two on ring wrap) — no per-event Python.
        """
        ts = np.asarray(ts, float)
        if ts.shape[0] == 0:
            return
        self._append_batch(track, self._nid(name), ts, dur, lane, frame,
                           a0, a1)

    def instant_batch(self, track: int, name, ts: np.ndarray, *, lane=0,
                      frame=-1, a0=math.nan, a1=math.nan) -> None:
        self.span_batch(track, name, ts, _INSTANT, lane=lane, frame=frame,
                        a0=a0, a1=a1)

    # -- readback -----------------------------------------------------------
    @property
    def n_events(self) -> int:
        """Events currently held (≤ capacity)."""
        return min(self.seq, self.capacity)

    @property
    def n_dropped(self) -> int:
        """Events overwritten by the flight recorder (ring wrapped)."""
        return self.seq - self.n_events

    def events(self) -> dict[str, np.ndarray]:
        """The live window as arrays, oldest-first in append order.  Names
        and tracks come back as string arrays; spans have ``dur >= 0``,
        instants ``dur == -1``."""
        n = self.n_events
        idx = (np.arange(self.seq - n, self.seq) % self.capacity
               if n else np.zeros(0, np.int64))
        names = np.array(self._names) if self._names else np.zeros(0, "U1")
        return {
            "ts": self._ts[idx].copy(),
            "dur": self._dur[idx].copy(),
            "name": names[self._name[idx]] if n else np.zeros(0, "U1"),
            "track": np.array(self._tracks)[self._track[idx]]
            if n else np.zeros(0, "U1"),
            "lane": self._lane[idx].copy(),
            "frame": self._frame[idx].copy(),
            "a0": self._a0[idx].copy(),
            "a1": self._a1[idx].copy(),
        }

    def select(self, name: str) -> dict[str, np.ndarray]:
        """Live events with this name, oldest-first (the audit test's
        join key: batch appends preserve emission order)."""
        ev = self.events()
        m = ev["name"] == name
        return {k: v[m] for k, v in ev.items()}

    # -- export -------------------------------------------------------------
    def export_chrome(self, path) -> int:
        """Write Chrome trace-event JSON (object format, ``traceEvents``)
        loadable in Perfetto; returns the number of events written.

        Mapping: track → pid (named via ``process_name`` metadata), lane →
        tid, span → phase ``X`` with ``dur``, instant → phase ``i``.
        Timestamps are exported in microseconds (the format's unit).
        """
        ev = self.events()
        out: list[dict] = []
        used = {(t, int(lane)) for t, lane in zip(ev["track"], ev["lane"])}
        for track, lane in sorted(used):
            pid = self._track_ids[track]
            out.append({"ph": "M", "pid": pid, "tid": 0,
                        "name": "process_name", "args": {"name": track}})
            out.append({"ph": "M", "pid": pid, "tid": lane,
                        "name": "thread_name",
                        "args": {"name": f"{track}/{lane}"}})
        base_seq = self.seq - self.n_events
        for i in range(ev["ts"].shape[0]):
            nid = self._name_ids[str(ev["name"][i])]
            a0l, a1l = self._arg_labels[nid]
            args: dict = {}
            if math.isfinite(ev["a0"][i]):
                args[a0l] = float(ev["a0"][i])
            if math.isfinite(ev["a1"][i]):
                args[a1l] = float(ev["a1"][i])
            if int(ev["frame"][i]) >= 0:
                args["frame"] = int(ev["frame"][i])
            args.update(self._rich.get(base_seq + i, {}))
            rec = {"name": str(ev["name"][i]),
                   "pid": self._track_ids[str(ev["track"][i])],
                   "tid": int(ev["lane"][i]),
                   "ts": float(ev["ts"][i]) * 1e6,
                   "args": args}
            if ev["dur"][i] >= 0.0:
                rec["ph"] = "X"
                rec["dur"] = float(ev["dur"][i]) * 1e6
            else:
                rec["ph"] = "i"
                rec["s"] = "t"
            out.append(rec)
        with open(path, "w") as fh:
            json.dump({"traceEvents": out,
                       "displayTimeUnit": "ms",
                       "otherData": {"n_dropped": self.n_dropped}}, fh)
        return len(out)


class NullTracer:
    """The default tracer: every emit is a no-op and ``enabled`` is False,
    so call sites guard argument preparation and the traced-off hot path
    stays bit-identical to untraced code."""

    enabled = False
    capacity = 0
    seq = 0
    n_events = 0
    n_dropped = 0

    def now(self) -> float:
        return 0.0

    def track(self, name: str) -> int:
        return -1

    def intern(self, name: str, a0_label: str = "a0",
               a1_label: str = "a1") -> int:
        return -1

    def span(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def span_batch(self, *a, **kw) -> None:
        pass

    def instant_batch(self, *a, **kw) -> None:
        pass

    def events(self) -> dict[str, np.ndarray]:
        return {k: np.zeros(0) for k in
                ("ts", "dur", "name", "track", "lane", "frame", "a0", "a1")}

    def select(self, name: str) -> dict[str, np.ndarray]:
        return self.events()

    def export_chrome(self, path) -> int:
        with open(path, "w") as fh:
            json.dump({"traceEvents": []}, fh)
        return 0


NULL_TRACER = NullTracer()
