"""Sharded AdamW with global-norm clipping and cosine schedule.

Optimizer state mirrors the parameter pytree (m, v) so GSPMD shards it
exactly like the parameters (ZeRO-style when params are FSDP-sharded).
Pure-functional: ``init(params) -> state``, ``update(grads, state, params,
step) -> (new_params, new_state)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def init(params: Any) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads: Any, state: dict, params: Any
           ) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
