from . import compression
from .adamw import AdamWConfig, global_norm, init, schedule, update

__all__ = ["AdamWConfig", "compression", "global_norm", "init", "schedule",
           "update"]
