"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback (EF-SGD style).

On the multi-pod mesh the ``pod`` axis rides the slow DCN; compressing the
gradient contribution before the cross-pod reduction cuts that collective's
bytes 4× (f32→int8) at no asymptotic accuracy cost thanks to the error
buffer.  Enabled via ``TrainConfig.grad_compression`` — the quant/dequant
pair is exact-inverse-tested and the EF accumulator property-tested.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(grads: Any, error: Any) -> tuple[Any, Any]:
    """Quantize (grads + error); the residual goes back into ``error``.

    Returns (dequantized grads to feed the optimizer / collective, new error).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), target - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
