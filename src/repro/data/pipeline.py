"""Deterministic synthetic data pipeline — shardable, resumable, prefetched.

Production shape: the loader yields global batches whose per-host slice is
computed from (host_id, num_hosts); restore-from-step is exact (the stream
is a pure function of (seed, step)).  A background thread prefetches and
device-puts the next batch while the current step runs (overlap of input
pipeline with compute).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_stub_dim: int | None = None  # audio/vlm: yield embeddings instead


def _batch_at(cfg: DataConfig, step: int, host_id: int, num_hosts: int) -> dict:
    assert cfg.global_batch % num_hosts == 0
    per_host = cfg.global_batch // num_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))
    if cfg.embed_stub_dim:
        emb = rng.standard_normal(
            (per_host, cfg.seq_len, cfg.embed_stub_dim)).astype(np.float32)
        labels = rng.integers(0, cfg.vocab,
                              (per_host, cfg.seq_len), dtype=np.int32)
        return {"embeds": emb, "labels": labels}
    # Markov-ish synthetic tokens: loosely predictable so loss can fall.
    base = rng.integers(0, cfg.vocab, (per_host, cfg.seq_len), dtype=np.int32)
    shifted = np.roll(base, 1, axis=1)
    mix = rng.random((per_host, cfg.seq_len)) < 0.5
    tokens = np.where(mix, shifted, base).astype(np.int32)
    return {"tokens": tokens}


class DataLoader:
    """Iterator with exact resume: ``DataLoader(cfg, start_step=k)``."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 host_id: int = 0, num_hosts: int = 1, prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, step, self.host_id, self.num_hosts)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()


def batch_specs(cfg: DataConfig, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct stand-ins for dry-run lowering (global shapes)."""
    if cfg.embed_stub_dim:
        return {
            "embeds": jax.ShapeDtypeStruct(
                (cfg.global_batch, cfg.seq_len, cfg.embed_stub_dim), dtype),
            "labels": jax.ShapeDtypeStruct(
                (cfg.global_batch, cfg.seq_len), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct(
        (cfg.global_batch, cfg.seq_len), jnp.int32)}
