from .pipeline import DataConfig, DataLoader, batch_specs

__all__ = ["DataConfig", "DataLoader", "batch_specs"]
