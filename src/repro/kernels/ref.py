"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

These are the semantics of record: Pallas kernels are validated against these
in ``tests/test_kernels_*.py`` (interpret=True on CPU), and the CPU backend
dispatches here so smoke tests / examples run the same math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _row_shard(qf: jax.Array, n_kv: int, group: int, seq_dim: int = 1):
    """Sequence-parallel attention guard (§Perf iteration): when no head dim
    divides the tensor-parallel axis, GSPMD shards the qk *contraction* and
    ALL-REDUCES the full S×S logits (measured 43 GB/layer f32 on llama4
    prefill).  Constraining q's row dim onto `model` makes the logits
    row-sharded instead — zero attention collectives."""
    from ..parallel.sharding import active_mesh, constrain
    mesh, axes = active_mesh()
    if mesh is None:
        return qf
    msize = mesh.shape[axes.model]
    if n_kv % msize == 0 or group % msize == 0:
        return qf  # head parallelism already available
    # Row-sharding q forces k/v replication across `model`; only profitable
    # when the k/v head volume is modest (refuted on MLA's 40 full heads —
    # §Perf: minicpm3 regressed 2×, gate added).
    d = qf.shape[-1]
    if n_kv * d > 2048:  # MLA's 40×96 regressed 2×; musicgen's 24×64 wins
        return qf
    names: list[str | None] = [None] * qf.ndim
    names[seq_dim] = "model"
    return constrain(qf, tuple(names))


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None,
              kv_offset: int = 0) -> jax.Array:
    """Full (flash-equivalent) attention with GQA head broadcast.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``kv_offset``: absolute position of q[0] minus position of k[0]
    (prefill: 0 with Sq == Skv; decode: cache_len with Sq == 1).
    window: sliding-window size (attend to positions in (i-window, i]).

    Sliding-window inputs long enough to profit are routed to the banded
    implementation (O(S·w) instead of O(S²) — §Perf iteration 1).
    """
    if (causal and window is not None and kv_offset == 0
            and q.shape[1] == k.shape[1] and q.shape[1] > 2 * window):
        return attention_banded(q, k, v, window=window, scale=scale)
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = _row_shard(qf.reshape(B, Sq, Hkv, g, D), Hkv, g, seq_dim=1)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    q_pos = jnp.arange(Sq)[:, None] + kv_offset
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def attention_banded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, scale: float | None = None) -> jax.Array:
    """Exact causal sliding-window attention in O(S·window).

    Chunks the sequence into window-sized blocks; each query block attends
    to its own and the previous block only (the (q-window, q] band is fully
    contained there).  Equals the masked full attention bit-for-bit on the
    valid band — validated in tests against :func:`attention`.
    """
    B, S, Hq, D = q.shape
    _, _, Hkv, _ = k.shape
    g = Hq // Hkv
    w = window
    scale = scale if scale is not None else D ** -0.5
    pad = (-S) % w
    if pad:
        def zf(t):
            return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zf(q), zf(k), zf(v)
    Sp = S + pad
    nc = Sp // w
    # NOTE: no _row_shard here — banded logits are O(S·w), and measurement
    # showed the q/k/v re-shard costs more than it saves (§Perf, refuted).
    qf = (q.astype(jnp.float32) * scale).reshape(B, nc, w, Hkv, g, D)
    kc = k.astype(jnp.float32).reshape(B, nc, w, Hkv, D)
    vc = v.astype(jnp.float32).reshape(B, nc, w, Hkv, D)
    # band for chunk i: [chunk i-1 | chunk i]  (chunk -1 zero-padded)
    def prev(t):
        return jnp.concatenate([jnp.zeros_like(t[:, :1]), t[:, :-1]], axis=1)
    kb = jnp.concatenate([prev(kc), kc], axis=2)        # (B, nc, 2w, Hkv, D)
    vb = jnp.concatenate([prev(vc), vc], axis=2)
    logits = jnp.einsum("bcqhgd,bckhd->bchgqk", qf, kb)  # (B,nc,Hkv,g,w,2w)
    q_pos = jnp.arange(w)[:, None] + w                   # within-band coords
    k_pos = jnp.arange(2 * w)[None, :]
    first = jax.lax.broadcasted_iota(jnp.int32, (nc, 1, 1), 0) == 0
    mask = (k_pos <= q_pos) & (k_pos > q_pos - w)
    mask = mask[None] & ~(first & (k_pos < w))           # chunk 0 has no prev
    logits = jnp.where(mask[:, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bchgqk,bckhd->bcqhgd", p, vb)
    out = out.reshape(B, Sp, Hq, D)[:, :S]
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int, *,
                     window: int | None = None,
                     scale: float | None = None) -> jax.Array:
    """Single-token attention over a (possibly ring-buffered) KV cache.

    q: (B, Hq, D); caches: (B, Smax, Hkv, D); cache_len: #valid entries
    (scalar or (B,)).  With ``window``, the cache is a ring buffer of size
    ``window`` — all *valid* slots participate (ring order does not matter
    for softmax since positions are compared via validity only).
    """
    B, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    lens = jnp.asarray(cache_len)
    lens = jnp.broadcast_to(lens, (B,))
    valid = jnp.arange(Smax)[None, :] < jnp.minimum(lens, Smax)[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, v_cache.shape[-1]).astype(q.dtype)


def ssd_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
             h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Reference selective-state-space scan (Mamba-2 SSD form), sequential.

    Recurrence per head: h_t = a_t * h_{t-1} + b_t ⊗ x_t;  y_t = h_t @ c_t.
      x: (B, S, H, P)   — inputs (P = head dim)
      a: (B, S, H)      — scalar decay per head/step, in (0, 1)
      b: (B, S, H, N)   — input projection onto state (N = d_state)
      c: (B, S, H, N)   — output projection
      h0: (B, H, P, N)  — initial state (zeros if None)
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    dt = x.dtype
    xf, af, bf, cf = (t.astype(jnp.float32) for t in (x, a, b, c))
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, at, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        h = h * at[..., None, None] + xt[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(dt), h


def mlstm_scan(q: jax.Array, k: jax.Array, v: jax.Array, i_gate: jax.Array,
               f_gate: jax.Array, c0: jax.Array | None = None,
               n0: jax.Array | None = None, m0: jax.Array | None = None
               ) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Reference mLSTM (xLSTM matrix-memory cell), sequential & stabilized.

    q,k,v: (B, S, H, P); i_gate,f_gate: (B, S, H) pre-activation log gates.
    C_t = f C_{t-1} + i k vᵀ; n_t = f n_{t-1} + i k; y = Cᵀq / max(|nᵀq|,1)
    with the m-state log-stabilizer of the xLSTM paper.
    Returns y (B,S,H,P) and final (C, n, m).
    """
    B, S, H, P = q.shape
    dt = q.dtype
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    i_f = i_gate.astype(jnp.float32)
    f_f = f_gate.astype(jnp.float32)
    scale = P ** -0.5
    C = jnp.zeros((B, H, P, P), jnp.float32) if c0 is None else c0.astype(jnp.float32)
    n = jnp.zeros((B, H, P), jnp.float32) if n0 is None else n0.astype(jnp.float32)
    m = jnp.full((B, H), -jnp.inf, jnp.float32) if m0 is None else m0.astype(jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_act = jnp.exp(it - m_new)
        f_act = jnp.exp(logf + m - m_new)
        kt = kt * scale
        C = C * f_act[..., None, None] + i_act[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = n * f_act[..., None] + i_act[..., None] * kt
        num = jnp.einsum("bhpk,bhp->bhk", C, qt)
        # clamp at exp(−m): equals 1.0 in unstabilized ("true") space
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qt)),
                          jnp.exp(-m_new))
        y = num / den[..., None]
        return (C, n, m_new), y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, i_f, f_f))
    (C, n, m), ys = jax.lax.scan(step, (C, n, m), xs)
    return jnp.moveaxis(ys, 0, 1).astype(dt), (C, n, m)
