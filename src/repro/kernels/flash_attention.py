"""Flash attention — Pallas TPU kernel (online-softmax, VMEM-tiled).

TPU adaptation notes (vs. the CUDA flash-attention the literature targets):
no warps/shared-memory banking — instead we tile (Sq × Skv) into
``(block_q × block_k)`` VMEM blocks sized for the MXU (multiples of 128 on
the lane dim), keep the running max / denominator / f32 accumulator in VMEM
scratch carried across the innermost KV grid dim, and finalize the output
block on the last KV step.  Causal + sliding-window masks are applied with
block-position iotas.  GQA is handled by folding query-head groups onto
their KV head (head-major batch fold).

Oracle: ``ref.attention`` — swept over shapes/dtypes in
``tests/test_kernels_attention.py`` (interpret=True on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    kv_offset=0, block_q=128, block_k=128, interpret=False):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D) → (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))

    # head-major fold: (B*Hq, S, D); KV repeated per group
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(B * Hq, Skv, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(B * Hq, Skv, D)

    Sq_p = pl.cdiv(Sq, block_q) * block_q
    Skv_p = pl.cdiv(Skv, block_k) * block_k
    if Sq_p != Sq:
        qf = jnp.pad(qf, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        kf = jnp.pad(kf, ((0, 0), (0, Skv_p - Skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Skv_p - Skv), (0, 0)))

    grid = (B * Hq, Sq_p // block_q, Skv_p // block_k)

    def kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        ki = pl.program_id(2)

        @pl.when(ki == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        qb = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        kb = k_ref[0].astype(jnp.float32)                  # (bk, d)
        vb = v_ref[0].astype(jnp.float32)
        s = qb @ kb.T                                      # (bq, bk)

        qi = pl.program_id(1)
        q_pos = (qi * block_q + kv_offset
                 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < Skv
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        acc_ref[...] = acc_ref[...] * alpha + p @ vb
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)

        @pl.when(ki == pl.num_programs(2) - 1)
        def _final():
            o_ref[0] = (acc_ref[...]
                        / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :Sq].reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
