"""Fused RMSNorm — Pallas TPU kernel.

One pass per row block: accumulate Σx² in f32, rsqrt, scale — fused so the
activation is read once from HBM (the jnp version reads it twice: once for
the variance reduction, once for the normalize).  Rows are tiled to
``block_rows`` and the feature dim stays whole in VMEM (d_model ≤ 8192
across our archs → ≤ 64KB/row f32, well within VMEM with small row blocks).

Oracle: ``ref.rmsnorm``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rmsnorm(x, scale, *, eps=1e-5, block_rows=256, interpret=False):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    block_rows = min(block_rows, max(n, 1))
    n_p = pl.cdiv(n, block_rows) * block_rows
    if n_p != n:
        x2 = jnp.pad(x2, ((0, n_p - n), (0, 0)))

    def kernel(x_ref, s_ref, o_ref):
        xf = x_ref[...].astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        o_ref[...] = (xf * jax.lax.rsqrt(var + eps)
                      * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(n_p // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_p, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:n].reshape(orig_shape)
