"""Pallas TPU kernels (+ pure-jnp oracles and backend dispatch).

flash_attention  — tiled online-softmax attention (causal/SWA/GQA)
decode_attention — single-token flash-decode over (ring) KV caches
ssm_scan         — chunked SSD selective scan, sequential-grid state carry
rmsnorm          — fused single-pass row norm
chunked          — XLA (backend-portable) chunked SSD / mLSTM
ref              — oracles; ops — per-backend dispatch
"""

from . import chunked, ops, ref

__all__ = ["chunked", "ops", "ref"]
