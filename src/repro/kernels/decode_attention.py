"""Decode attention — Pallas TPU kernel (flash-decode style).

Single new token per sequence attending over a long KV cache: the cache is
swept in ``block_k`` VMEM tiles with online-softmax state in VMEM scratch;
queries (one vector per (batch, q-head)) stay resident.  Ring-buffer SWA
caches work unchanged — validity masking is per-slot (`len`), not
positional, matching ``ref.decode_attention``.

Memory-bound by design: the roofline term for ``decode_*`` shapes is HBM
bytes (the whole cache is read once); the kernel's job is to reach that
bound by never spilling the accumulator and streaming K/V tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     scale=None, block_k=512, interpret=False):
    """q: (B,Hq,D); caches: (B,Smax,Hkv,D); cache_len: scalar/(B,) valid
    slots → (B,Hq,D)."""
    B, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, max(Smax, 8))

    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    # head-major fold: q (B*Hkv, g, D); caches (B*Hkv, Smax, D)
    qf = q.reshape(B, Hkv, g, D).reshape(B * Hkv, g, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, Smax, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, Smax, D)
    lens_f = jnp.repeat(lens, Hkv)

    Smax_p = pl.cdiv(Smax, block_k) * block_k
    if Smax_p != Smax:
        kf = jnp.pad(kf, ((0, 0), (0, Smax_p - Smax), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Smax_p - Smax), (0, 0)))

    grid = (B * Hkv, Smax_p // block_k)

    def kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        ki = pl.program_id(1)

        @pl.when(ki == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        qb = q_ref[0].astype(jnp.float32) * scale          # (g, d)
        kb = k_ref[0].astype(jnp.float32)                  # (bk, d)
        vb = v_ref[0].astype(jnp.float32)
        s = qb @ kb.T                                      # (g, bk)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos < jnp.minimum(len_ref[0], Smax)
        s = jnp.where(valid, s, _NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        acc_ref[...] = acc_ref[...] * alpha + p @ vb
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)

        @pl.when(ki == pl.num_programs(1) - 1)
        def _final():
            o_ref[0] = (acc_ref[...]
                        / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, D), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lens_f, qf, kf, vf)
    return out.reshape(B, Hq, D)
