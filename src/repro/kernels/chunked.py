"""Chunked (sub-quadratic, scan-over-chunks) SSD and mLSTM — XLA path.

The production forward pass for SSM/xLSTM blocks: O(S·Q) instead of O(S²),
with a ``lax.scan`` over chunks carrying the recurrent state.  This is the
TPU-friendly Mamba-2 "state-space duality" formulation; the Pallas kernel in
``ssm_scan.py`` fuses one chunk's work into VMEM, this module is the
backend-portable version (and the oracle used to cross-check the kernel is
``ref.ssd_scan`` — sequential, trivially correct).

Numerics: per-chunk log-space cumulative decays; pairwise differences inside
a chunk keep every exponent ≤ 0, so no overflow; f32 accumulation throughout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Dry-run probe knob: XLA's cost model counts a while-loop body ONCE, so the
# roofline probes lower with all scans unrolled (launch/dryrun.py sets this
# around probe lowering only — never for real execution).
UNROLL_SCANS = False


def _unroll(length: int) -> int:
    return length if UNROLL_SCANS else 1


def _chunk(x: jax.Array, q: int) -> jax.Array:
    b, s = x.shape[0], x.shape[1]
    return x.reshape(b, s // q, q, *x.shape[2:])


def ssd_scan_chunked(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                     h0: jax.Array | None = None, *, chunk: int = 256
                     ) -> tuple[jax.Array, jax.Array]:
    """Chunked evaluation of ``ref.ssd_scan`` (same signature + chunk).

    x: (B,S,H,P), a: (B,S,H) in (0,1), b/c: (B,S,H,N).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    dt = x.dtype
    Q = min(chunk, S)
    if S % Q:
        pad = Q - S % Q
        def zf(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, b, c = zf(x), zf(b), zf(c)
        a = jnp.pad(a, [(0, 0), (0, pad), (0, 0)], constant_values=1.0)
    Sp = x.shape[1]
    xf = _chunk(x.astype(jnp.float32), Q)      # (B,G,Q,H,P)
    bf = _chunk(b.astype(jnp.float32), Q)      # (B,G,Q,H,N)
    cf = _chunk(c.astype(jnp.float32), Q)
    la = _chunk(jnp.log(jnp.maximum(a.astype(jnp.float32), 1e-37)), Q)  # (B,G,Q,H)
    cum = jnp.cumsum(la, axis=2)               # logA_t within chunk
    total = cum[:, :, -1]                      # (B,G,H)

    # Intra-chunk: score[t,s] = (c_t · b_s) · exp(logA_t − logA_s + log a_s…)
    # recurrence h_t = a_t h_{t-1} + b_t x_t includes a_t *before* adding b_t x_t
    # only for previous state; the s-th injection decays by ∏_{u=s+1..t} a_u
    # = exp(cum_t − cum_s).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,G,Q,Q,H) t,s
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask BEFORE exp: upper-triangle diffs are positive-large; exp(inf)·0
    # in the where-gradient would poison the backward pass with NaNs
    gate = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    dots = jnp.einsum("bgthn,bgshn->bgtsh", cf, bf)       # c_t · b_s
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp", dots * gate, xf)

    # Chunk summaries: injected state  Σ_s exp(cum_end − cum_s) b_s ⊗ x_s
    w = jnp.exp(total[:, :, None] - cum)                  # (B,G,Q,H)
    h_in = jnp.einsum("bgqh,bgqhn,bgqhp->bghpn", w, bf, xf)

    # Scan chunks: carry h (B,H,P,N)
    h_init = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inp):
        h_inj, tot = inp                                  # (B,H,P,N), (B,H)
        h_out = h                                        # state BEFORE chunk
        h = h * jnp.exp(tot)[..., None, None] + h_inj
        return h, h_out

    hs_in = (jnp.moveaxis(h_in, 1, 0), jnp.moveaxis(total, 1, 0))
    n_chunks = h_in.shape[1]
    h_final, h_starts = jax.lax.scan(step, h_init, hs_in,
                                     unroll=_unroll(n_chunks))
    h_starts = jnp.moveaxis(h_starts, 0, 1)               # (B,G,H,P,N)

    # Inter-chunk: y_t += exp(cum_t) · (c_t · h_start)
    y_inter = jnp.einsum("bgthn,bghpn->bgthp", cf, h_starts) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    return y.astype(dt), h_final


def mlstm_chunked(q: jax.Array, k: jax.Array, v: jax.Array, i_gate: jax.Array,
                  f_gate: jax.Array, *, chunk: int = 256
                  ) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Chunked mLSTM forward (training path).

    Maps the xLSTM matrix-memory cell onto two SSD scans sharing decays:
      C_t = f̂ C_{t-1} + î k v’  → ssd(x=v, a=f̂, b=î·k, c=q)   (numerator)
      n_t = f̂ n_{t-1} + î k    → ssd(x=1, …)                  (denominator)
    Gates are stabilized per-sequence by the running max trick only at the
    sequential reference; here exponential gates are tamed by log-sigmoid
    forget decays (≤ 0 exponents) and a global input-gate max subtraction,
    matching ``ref.mlstm_scan`` to f32 tolerance for bounded gate ranges.
    """
    B, S, H, P = q.shape
    dt = q.dtype
    scale = P ** -0.5
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))           # ≤ 0
    li = i_gate.astype(jnp.float32)
    m = jnp.maximum(jnp.max(li, axis=1, keepdims=True), 0.0)        # (B,1,H)
    i_act = jnp.exp(li - m)
    a = jnp.exp(logf)                                               # decay
    kf = k.astype(jnp.float32) * scale
    b = kf * i_act[..., None]
    num, C = ssd_scan_chunked(v, a, b, q, chunk=chunk)              # (B,S,H,P)
    ones = jnp.ones((B, S, H, 1), jnp.float32)
    den, n = ssd_scan_chunked(ones, a, b, q, chunk=chunk)           # (B,S,H,1)
    den = jnp.maximum(jnp.abs(den[..., 0]), jnp.exp(-m))            # un-scaled ≥ 1
    y = num.astype(jnp.float32) / den[..., None]
    m_out = jnp.broadcast_to(m[:, 0], (B, H))
    return y.astype(dt), (C, n[:, :, 0, :], m_out)  # n state: (B,H,P=1,N)→(B,H,N)
