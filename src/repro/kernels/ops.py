"""Backend dispatch for the perf-critical ops.

TPU  → Pallas kernels (``flash_attention.py``, ``decode_attention.py``,
       ``ssm_scan.py``, ``rmsnorm.py``).
CPU/other → jnp paths: ``ref.py`` oracles for attention/rmsnorm and the
       chunked sub-quadratic scans in ``chunked.py`` for SSD/mLSTM.

``REPRO_KERNELS`` env overrides: "xla" (force jnp), "pallas" (force Pallas,
interpret=True off-TPU — used by kernel tests).
"""

from __future__ import annotations

import functools
import os

import jax

from . import chunked, ref


@functools.cache
def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env != "auto":
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, *, causal=True, window=None, scale=None, kv_offset=0):
    if _mode() == "pallas":
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, kv_offset=kv_offset,
                               interpret=_interpret())
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale,
                         kv_offset=kv_offset)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     scale=None):
    if _mode() == "pallas":
        from .decode_attention import decode_attention as da
        return da(q, k_cache, v_cache, cache_len, window=window, scale=scale,
                  interpret=_interpret())
    return ref.decode_attention(q, k_cache, v_cache, cache_len, window=window,
                                scale=scale)


def rmsnorm(x, scale, eps=1e-5):
    if _mode() == "pallas":
        from .rmsnorm import rmsnorm as rn
        return rn(x, scale, eps=eps, interpret=_interpret())
    return ref.rmsnorm(x, scale, eps)


def ssd_scan(x, a, b, c, h0=None, *, chunk=256):
    if _mode() == "pallas":
        from .ssm_scan import ssd_scan_pallas
        return ssd_scan_pallas(x, a, b, c, h0=h0, chunk=chunk,
                               interpret=_interpret())
    return chunked.ssd_scan_chunked(x, a, b, c, h0=h0, chunk=chunk)


def mlstm_scan(q, k, v, i_gate, f_gate, *, chunk=256):
    # mLSTM rides on the SSD machinery in both backends (see chunked.py).
    return chunked.mlstm_chunked(q, k, v, i_gate, f_gate, chunk=chunk)
