"""Chunked selective-scan (SSD) — Pallas TPU kernel.

One grid step processes one (batch·head, chunk) tile entirely in VMEM:
builds the chunk-local decay matrix G[t,s] = exp(cumlog a_t − cumlog a_s),
computes the intra-chunk quadratic term ((C·Bᵀ)⊙G)·X on the MXU, applies
the carried state h (inter-chunk term), and writes the updated state for
the next chunk — the sequential chunk dependency is expressed by making
the chunk index the innermost grid dim with the state in VMEM scratch
(grid iterations on TPU are sequential per core, so the carry is legal;
this is the TPU-idiomatic replacement for the CUDA kernel's cross-block
semaphore chain).

Oracle: ``ref.ssd_scan`` (sequential); the XLA path is
``chunked.ssd_scan_chunked``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ssd_scan_pallas(x, a, b, c, h0=None, *, chunk=256, interpret=False):
    """x: (B,S,H,P); a: (B,S,H) decay ∈ (0,1); b,c: (B,S,H,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    dt = x.dtype
    Q = min(chunk, S)
    pad = (Q - S % Q) % Q
    if pad:
        def zf(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, b, c = zf(x), zf(b), zf(c)
        a = jnp.pad(a, [(0, 0), (0, pad), (0, 0)], constant_values=1.0)
    Sp = S + pad
    G = Sp // Q

    # head-major fold: (B*H, S, ·)
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Sp, P)
    bf = b.transpose(0, 2, 1, 3).reshape(B * H, Sp, N)
    cf = c.transpose(0, 2, 1, 3).reshape(B * H, Sp, N)
    la = jnp.log(jnp.maximum(a.astype(jnp.float32), 1e-37))
    laf = la.transpose(0, 2, 1).reshape(B * H, Sp)
    h_init = (jnp.zeros((B * H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32).reshape(B * H, P, N))

    def kernel(x_ref, b_ref, c_ref, la_ref, h0_ref, y_ref, hout_ref, h_ref):
        gi = pl.program_id(1)

        @pl.when(gi == 0)
        def _init():
            h_ref[...] = h0_ref[0]

        xb = x_ref[0].astype(jnp.float32)            # (Q, P)
        bb = b_ref[0].astype(jnp.float32)            # (Q, N)
        cb = c_ref[0].astype(jnp.float32)
        lab = la_ref[0].astype(jnp.float32)          # (Q,)
        cum = jnp.cumsum(lab)                        # logA_t
        diff = cum[:, None] - cum[None, :]           # (Q, Q) t,s
        tri = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0) >= \
            jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
        gate = jnp.where(tri, jnp.exp(diff), 0.0)
        dots = cb @ bb.T                             # (Q, Q): c_t · b_s
        y = (dots * gate) @ xb                       # intra-chunk (Q, P)
        h = h_ref[...]                               # (P, N) carried state
        y = y + jnp.exp(cum)[:, None] * (cb @ h.T)   # inter-chunk
        y_ref[0] = y.astype(y_ref.dtype)
        w = jnp.exp(cum[-1] - cum)                   # (Q,)
        h_inj = xb.T @ (bb * w[:, None])             # (P, N)
        h_ref[...] = h * jnp.exp(cum[-1]) + h_inj

        @pl.when(gi == pl.num_programs(1) - 1)
        def _final():
            hout_ref[0] = h_ref[...]

    y, h_final = pl.pallas_call(
        kernel,
        grid=(B * H, G),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda i, g: (i, g, 0)),
            pl.BlockSpec((1, Q, N), lambda i, g: (i, g, 0)),
            pl.BlockSpec((1, Q, N), lambda i, g: (i, g, 0)),
            pl.BlockSpec((1, Q), lambda i, g: (i, g)),
            pl.BlockSpec((1, P, N), lambda i, g: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda i, g: (i, g, 0)),
            pl.BlockSpec((1, P, N), lambda i, g: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, P), dt),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xf, bf, cf, laf, h_init)
    y = y.reshape(B, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
    return y.astype(dt), h_final.reshape(B, H, P, N)
