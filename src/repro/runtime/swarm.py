"""Event-driven swarm serving simulator: streaming requests on a moving swarm.

The paper's static instances answer "where do the layers go *right now*";
this simulator answers the question the paper actually motivates OULD-MP
with: how do placement policies behave when the network changes *under* the
computation — UAVs move (link rates drift, inter-group links fade beyond
range), nodes drop out and rejoin, and classification requests arrive as a
Poisson stream instead of one batch.

Simulator knobs → paper sections
--------------------------------
========================  ====================================================
knob                      paper grounding
========================  ====================================================
``n_groups``/``area_m``   §III-C RPG mobility [40]; multi-group sweeps make
                          inter-group links cross ``max_range`` (ρ→0), the
                          disconnection argument of Fig. 13
``tick_s``                §III-C time-step Δt at which positions are recorded
                          and ρ(t) re-sampled via Eq. (1) (``core/radio.py``)
``epoch_ticks``           §III-C re-optimization period: OULD re-solves on the
                          fresh snapshot, OULD-MP once per epoch over the
                          predicted horizon (Eq. 14; T = epoch_ticks)
``arrival_rate_hz``       §IV "incoming requests" axis (Fig. 4–7 sweeps load;
                          here load arrives as a Poisson stream)
``hold_ticks_mean``       §III-A each request is a surveillance stream served
                          every time step until its source stops capturing
``mem_mb``/``gflops``     §IV node calibration: {256, 512} MB, 9.5 GFLOPS
``deadline_s``            §I surveillance timeliness requirement (deadline
                          misses are the cost of serving over a faded link)
``mtbf_s``/``mttr_s``     §III-C "UAVs may leave the swarm" — unpredicted
                          churn, invisible to both OULD and OULD-MP horizons
========================  ====================================================

Policies are registered *planners* (see :mod:`repro.core.planner`): the
simulator's epoch loop is strategy-agnostic — it builds the richest
:class:`~repro.core.planner.TopologyView` each planner prefers (a predicted
horizon for ``ould-mp``, the fresh snapshot otherwise) and calls
``plan()`` through one :class:`~repro.runtime.serve.AdmissionController`.
``incremental`` is the warm-started snapshot OULD of PR 1;
``incremental-sparse`` the same warm loop over the k-candidate pruned DP
(the N ≥ 50 engine; ``SwarmScenario.sparse_k`` overrides its √N candidate
budget); ``ould-mp`` the horizon objective; ``nearest``/``hrm``/
``nearest-hrm`` the stateless §IV-A heuristics.  All policies consume the
identical event tape (same seed ⇒ same arrivals, holds, churn,
trajectories), so per-request metrics are paired.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.events import EventKind, EventQueue, churn_events, poisson_process
from ..core.latency import evaluate
from ..core.mobility import MultiGroupMobility, RPGParams
from ..core.ould import Problem
from ..core.placement import to_stages
from ..core.planner import (HorizonView, NoisyHorizonView, SnapshotView,
                            StaleView, available_planners, make_view)
from ..core.profiles import ModelProfile, lenet_profile
from ..core.radio import RadioParams, rate_matrix
from .serve import AdmissionController

# Canonical registry names for the scenario matrix …
PLANNER_POLICIES = ("incremental", "incremental-sparse", "ould-mp", "nearest",
                    "hrm", "nearest-hrm")
# … and the PR-1 policy aliases they replaced (kept for one release).
POLICY_ALIASES = {"ould": "incremental", "ould_mp": "ould-mp",
                  "nearest_hrm": "nearest-hrm"}
POLICIES = PLANNER_POLICIES

MB = 1e6


@dataclasses.dataclass(frozen=True)
class SwarmScenario:
    """One time-dynamic serving scenario (defaults ≈ paper §IV, 500 m area)."""

    n_uavs: int = 10
    n_groups: int = 2
    area_m: float = 500.0
    member_radius_m: float = 25.0
    leader_speed_mps: float = 5.0
    homogeneous: bool = False      # Fig. 2a: frozen intra-group geometry
    tick_s: float = 1.0
    duration_ticks: int = 120
    epoch_ticks: int = 15
    arrival_rate_hz: float = 0.15
    hold_ticks_mean: float = 45.0
    hotspots: int = 3              # request sources live in group 0
    mem_mb_hotspot_group: float = 192.0   # scarce: forces offload
    mem_mb_other_groups: float = 512.0    # paper's high-memory level
    comp_cap_flops: float = 95e9   # 9.5 GFLOPS × 10 s decision window
    gflops: float = 9.5e9
    deadline_s: float = 1.5
    mtbf_s: float = float("inf")   # churn off by default
    mttr_s: float = 30.0
    rel_change: float = 0.05       # incremental-solver link-drift threshold
    max_path_cost_s: float = 1e6   # admission bar: reject _BIG-priced paths
    sparse_k: int | None = None    # k-candidate budget for *-sparse planners
    # Degraded-view axis (ROADMAP): what the planner sees vs what serves.
    # None ⇒ the planner's preferred fresh view; "stale:<ticks>" ⇒ snapshot /
    # horizon captured that many ticks ago (StaleView); "noisy:<std>" ⇒
    # horizon rates with lognormal prediction error (NoisyHorizonView;
    # snapshot planners are unaffected — a snapshot is measured, not
    # predicted, so its degradation axis is staleness).
    view_degradation: str | None = None
    # Executed-latency sampling (repro.exec): serve latencies use measured
    # stage wall-clock (jitted apply_layers on this host) instead of the
    # analytic c_j/speed term; link delays stay priced per realized tick.
    execute: bool = False
    frame_hw: tuple[int, int, int] = (326, 595, 3)
    radio: RadioParams = RadioParams()

    def mobility(self, seed: int) -> MultiGroupMobility:
        return MultiGroupMobility(
            RPGParams(n_uavs=self.n_uavs, area_m=self.area_m,
                      member_radius_m=self.member_radius_m,
                      leader_speed_mps=self.leader_speed_mps,
                      step_s=self.tick_s, homogeneous=self.homogeneous),
            n_groups=self.n_groups, seed=seed)

    def mem_cap(self, group_of: np.ndarray) -> np.ndarray:
        return np.where(group_of == 0, self.mem_mb_hotspot_group * MB,
                        self.mem_mb_other_groups * MB)


@dataclasses.dataclass(frozen=True)
class StreamRequest:
    id: int
    source: int
    arrive_tick: int
    depart_tick: int


@dataclasses.dataclass
class EpochLog:
    tick: int
    n_active: int
    n_admitted: int
    n_kept: int
    n_replaced: int
    solve_time_s: float
    objective: float
    feasible: bool


@dataclasses.dataclass
class SimResult:
    policy: str
    n_arrivals: int
    n_never_admitted: int        # streams rejected at every epoch they lived
    served: int                  # serve attempts by admitted streams
    missed: int                  # serves beyond deadline (incl. link outage)
    latencies: np.ndarray        # finite realized per-serve latencies (s)
    epochs: list[EpochLog]

    @property
    def deadline_miss_rate(self) -> float:
        return self.missed / self.served if self.served else 0.0

    @property
    def rejection_rate(self) -> float:
        return self.n_never_admitted / self.n_arrivals if self.n_arrivals else 0.0

    @property
    def avg_latency_s(self) -> float:
        return float(self.latencies.mean()) if self.latencies.size else float("inf")

    @property
    def total_resolve_s(self) -> float:
        return float(sum(e.solve_time_s for e in self.epochs))


def _masked(rates: np.ndarray, alive: np.ndarray) -> np.ndarray:
    """Zero every link touching a dead node (ρ = 0 ⇔ disconnected)."""
    if alive.all():
        return rates
    out = rates.copy()
    if out.ndim == 3:                     # (T, N, N) horizon stack
        out[:, ~alive, :] = 0.0
        out[:, :, ~alive] = 0.0
    else:
        out[~alive, :] = 0.0
        out[:, ~alive] = 0.0
    return out


def _spb(rates: np.ndarray) -> np.ndarray:
    """(N,N) realized seconds/byte of one tick's snapshot (Eq. 1 inverted;
    matches Problem.transfer_cost's bits/s convention)."""
    with np.errstate(divide="ignore"):
        s = np.where(rates > 0, 8.0 / np.maximum(rates, 1e-30), np.inf)
    np.fill_diagonal(s, 0.0)
    return s


def _serve_once(path: np.ndarray, src: int, spb_t: np.ndarray,
                alive: np.ndarray, K: list[float], Ks: float,
                comp: list[float], speed: np.ndarray) -> float:
    """Realized end-to-end latency of one frame at one tick (inf = outage)."""
    if not alive[src] or not alive[path].all():
        return float("inf")
    lat = 0.0 if path[0] == src else Ks * spb_t[src, int(path[0])]
    for j in range(len(path)):
        i = int(path[j])
        lat += comp[j] / speed[i]
        if j + 1 < len(path) and path[j + 1] != i:
            lat += K[j] * spb_t[i, int(path[j + 1])]
    return float(lat)


def _serve_once_executed(path: np.ndarray, src: int, spb_t: np.ndarray,
                         alive: np.ndarray, K: list[float], Ks: float,
                         measure) -> float:
    """Executed-latency variant: per-stage *measured* wall-clock (``measure
    (layer_start, layer_end) → s``, repro.exec engine) replaces the analytic
    compute term; link delays stay priced per realized tick (Eq. 1)."""
    if not alive[src] or not alive[path].all():
        return float("inf")
    stages = to_stages(path)
    lat = (0.0 if stages[0].node == src
           else Ks * spb_t[src, stages[0].node])
    prev = stages[0].node
    for st in stages:
        if st.node != prev:
            lat += K[st.layer_start - 1] * spb_t[prev, st.node]
        lat += measure(st.layer_start, st.layer_end)
        prev = st.node
    return float(lat)


def _parse_degradation(spec: str | None) -> tuple[str, float] | None:
    """``"stale:3"`` / ``"noisy:0.25"`` → (mode, value)."""
    if spec is None:
        return None
    mode, _, val = spec.partition(":")
    if mode not in ("stale", "noisy"):
        raise ValueError(f"unknown view degradation {spec!r}; "
                         "use 'stale:<ticks>' or 'noisy:<std>'")
    return mode, float(val or 0.0)


def _stage_measurer(scn: SwarmScenario, profile: ModelProfile, seed: int):
    """Measured-seconds lookup for stage ranges: one ExecutionEngine per
    simulation, one jit + one measurement per unique (start, end) range —
    hotspot plans collapse to a handful of kernel timings."""
    from ..exec import ExecutionEngine, layer_fns_for  # lazy: pulls in jax

    engine = ExecutionEngine(layer_fns_for(profile))
    rng = np.random.default_rng(seed)
    frame = rng.standard_normal((1, *scn.frame_hw)).astype(np.float32)
    acts: dict[int, object] = {0: frame}   # boundary activations, lazily
    cache: dict[tuple[int, int], float] = {}

    def act_at(layer: int):
        if layer not in acts:
            acts[layer] = engine.closure(layer - 1, layer)(act_at(layer - 1))
        return acts[layer]

    def measure(layer_start: int, layer_end: int) -> float:
        key = (layer_start, layer_end)
        if key not in cache:
            cache[key] = engine.measure_range(layer_start, layer_end,
                                              act_at(layer_start))
        return cache[key]

    return measure


def simulate(scn: SwarmScenario, policy: str, seed: int = 0, *,
             profile: ModelProfile | None = None,
             cold_resolves: bool = False) -> SimResult:
    """Run one policy over the scenario's event tape.

    ``cold_resolves=True`` forces every epoch re-solve from scratch (the
    baseline the warm-started incremental path is measured against); it only
    affects solve *time*, never the event tape.
    """
    planner_name = POLICY_ALIASES.get(policy, policy)
    if planner_name not in available_planners():
        raise ValueError(f"unknown policy {policy!r}; one of "
                         f"{available_planners()} (or aliases "
                         f"{tuple(POLICY_ALIASES)})")
    profile = profile or lenet_profile()
    rng = np.random.default_rng(seed)
    T = scn.duration_ticks
    mob = scn.mobility(seed)
    pos = mob.positions(T, seed=seed + 1)
    rates_t = [rate_matrix(pos[t], scn.radio) for t in range(T)]

    mem_cap = scn.mem_cap(mob.group_of)
    comp_cap = np.full(scn.n_uavs, scn.comp_cap_flops)
    speed = np.full(scn.n_uavs, scn.gflops)
    K = profile.output_vector()
    Ks = profile.input_bytes
    comp = profile.compute_vector()

    # --- event tape (identical across policies for a given seed) -----------
    q = EventQueue()
    arrivals = poisson_process(rng, scn.arrival_rate_hz, T * scn.tick_s)
    streams: dict[int, StreamRequest] = {}
    for i, t_arr in enumerate(arrivals):
        hold = max(1, int(round(rng.exponential(scn.hold_ticks_mean))))
        src = int(rng.integers(0, min(scn.hotspots, scn.n_uavs)))
        at = int(t_arr / scn.tick_s)
        streams[i] = StreamRequest(i, src, at, min(at + hold, T))
        q.push(t_arr, EventKind.ARRIVAL, i)
        q.push(streams[i].depart_tick * scn.tick_s, EventKind.DEPARTURE, i)
    protected = frozenset(range(min(scn.hotspots, scn.n_uavs)))
    for ce in churn_events(rng, scn.n_uavs, T * scn.tick_s, scn.mtbf_s,
                           scn.mttr_s, protected=protected):
        q.push(ce.time, ce.kind, ce.node)
    for k in range(0, T, scn.epoch_ticks):
        q.push(k * scn.tick_s, EventKind.EPOCH)
    for t in range(T):
        q.push(t * scn.tick_s, EventKind.MOBILITY_TICK, t)

    # --- state -------------------------------------------------------------
    alive = np.ones(scn.n_uavs, bool)
    active: dict[int, StreamRequest] = {}
    placed: dict[int, np.ndarray] = {}     # stream id → current path
    ever_admitted: set[int] = set()
    # One option dict configures every strategy (planners ignore options they
    # don't consume) — the epoch loop below has no per-strategy branches.
    ctrl = AdmissionController(planner_name, solver="dp",
                               warm=not cold_resolves,
                               rel_change=scn.rel_change,
                               max_path_cost=scn.max_path_cost_s,
                               sparse_k=scn.sparse_k)
    wants_horizon = getattr(ctrl.planner, "preferred_view",
                            "snapshot") == "horizon"
    degradation = _parse_degradation(scn.view_degradation)
    measure = (_stage_measurer(scn, profile, seed) if scn.execute else None)

    epochs: list[EpochLog] = []
    latencies: list[float] = []
    served = missed = 0

    def build_view(tick: int):
        """The planner's view of the network at this epoch — fresh by
        default, degraded when the scenario asks (serving always happens on
        the realized per-tick rates, so the gap is measured, not assumed)."""
        stale = 0
        if degradation is not None and degradation[0] == "stale":
            stale = int(degradation[1])
        seen = max(0, tick - stale)
        if wants_horizon:     # the epoch's predicted rates (Eq. 14 horizon)
            end = min(seen + scn.epoch_ticks, T)
            view = HorizonView(np.stack(rates_t[seen:end]), alive.copy())
            if degradation is not None and degradation[0] == "noisy":
                view = NoisyHorizonView.corrupt(
                    view, degradation[1], seed=seed * 100003 + tick)
            return view
        if stale:
            return StaleView(rates_t[seen], alive.copy(), age_ticks=stale)
        return make_view(rates_t[tick], alive.copy())

    def replace_all(tick: int) -> None:
        nonlocal placed
        act = sorted(active.values(), key=lambda s: s.id)
        placed = {}
        if not act:
            epochs.append(EpochLog(tick, 0, 0, 0, 0, 0.0, 0.0, True))
            return
        sources = np.array([s.source for s in act], np.int64)
        ids = [s.id for s in act]
        view = build_view(tick)
        plan = ctrl.admit(Problem(profile, mem_cap, comp_cap, view.rates,
                                  sources, speed), view, request_ids=ids)
        stats = plan.solve_stats
        n_kept = stats.n_kept if stats is not None else 0
        n_rep = stats.n_replaced if stats is not None else len(act)
        for row, s in enumerate(act):
            if plan.admitted[row]:
                placed[s.id] = plan.assign[row]
                ever_admitted.add(s.id)
        # capacity invariant under the *snapshot* problem (Eq. 4/5)
        feas_prob = SnapshotView(rates_t[tick], alive.copy()).bind(
            Problem(profile, mem_cap, comp_cap, rates_t[tick], sources,
                    speed))
        ev = evaluate(feas_prob, plan.solution)
        epochs.append(EpochLog(tick, len(act), plan.n_admitted,
                               n_kept, n_rep, plan.solve_time_s,
                               plan.objective, ev.feasible))

    while q:
        ev = q.pop()
        if ev.kind == EventKind.ARRIVAL:
            active[ev.payload] = streams[ev.payload]
        elif ev.kind == EventKind.DEPARTURE:
            active.pop(ev.payload, None)
            placed.pop(ev.payload, None)
        elif ev.kind == EventKind.NODE_FAIL:
            alive[ev.payload] = False
        elif ev.kind == EventKind.NODE_REJOIN:
            alive[ev.payload] = True
        elif ev.kind == EventKind.EPOCH:
            replace_all(int(round(ev.time / scn.tick_s)))
        elif ev.kind == EventKind.MOBILITY_TICK:
            t = ev.payload
            spb_t = _spb(_masked(rates_t[t], alive))
            for sid, path in placed.items():
                s = streams[sid]
                if not (s.arrive_tick <= t < s.depart_tick):
                    continue
                if measure is not None:
                    lat = _serve_once_executed(path, s.source, spb_t, alive,
                                               K, Ks, measure)
                else:
                    lat = _serve_once(path, s.source, spb_t, alive, K, Ks,
                                      comp, speed)
                served += 1
                if lat > scn.deadline_s:
                    missed += 1
                if np.isfinite(lat):
                    # every finite serve counts toward the latency average —
                    # censoring over-deadline serves would reward missing
                    latencies.append(lat)

    n_never = sum(1 for s in streams.values() if s.id not in ever_admitted)
    return SimResult(policy, len(streams), n_never, served, missed,
                     np.asarray(latencies), epochs)


def compare_policies(scn: SwarmScenario, seed: int = 0,
                     policies=POLICIES,
                     profile: ModelProfile | None = None) -> dict[str, SimResult]:
    """Run every policy over the SAME event tape (paired comparison)."""
    return {p: simulate(scn, p, seed, profile=profile) for p in policies}


def warm_vs_cold(scn: SwarmScenario, seed: int = 0,
                 profile: ModelProfile | None = None) -> dict:
    """Measure what the incremental solver buys: identical OULD runs, one
    with warm epoch re-solves, one forced cold.  The event tape and placement
    *decisions* may only differ where the warm path keeps a placement the
    cold solve would recompute identically — the objective ratio reports any
    drift."""
    warm = simulate(scn, "incremental", seed, profile=profile,
                    cold_resolves=False)
    cold = simulate(scn, "incremental", seed, profile=profile,
                    cold_resolves=True)
    ratios = [w.objective / c.objective
              for w, c in zip(warm.epochs, cold.epochs)
              if c.objective > 0 and np.isfinite(c.objective)]
    return {
        "warm_solve_s": warm.total_resolve_s,
        "cold_solve_s": cold.total_resolve_s,
        "speedup": (cold.total_resolve_s / warm.total_resolve_s
                    if warm.total_resolve_s > 0 else float("inf")),
        "objective_ratio_max": max(ratios) if ratios else 1.0,
        "warm": warm,
        "cold": cold,
    }
