"""Event-driven swarm serving simulator: streaming requests on a moving
swarm, served through per-node queues.

The paper's static instances answer "where do the layers go *right now*";
this simulator answers the question the paper actually motivates OULD-MP
with: how do placement policies behave when the network changes *under* the
computation — UAVs move (link rates drift, inter-group links fade beyond
range), nodes drop out and rejoin, and classification requests arrive as a
Poisson stream instead of one batch.

Since the queueing-runtime refactor the serve path is layered, not
monolithic:

* :func:`build_event_tape` freezes the scenario's entire stochastic input —
  arrivals, holds, sources, deadline classes, churn — into an
  :class:`EventTape` before any policy runs, so every policy consumes the
  *identical* tape (same seed ⇒ paired per-request metrics) and the pairing
  is testable as data, not as a convention;
* the per-tick serve step is vectorized struct-of-arrays: one numpy pass
  prices every active stream's realized path latency for the tick and emits
  one *frame* per stream into its placed node's queue — a frame occupies
  the node hosting its heaviest stage for that stage's modeled (or
  measured, ``execute=True``) wall instead of completing instantly;
* :class:`~repro.runtime.queueing.NodeQueues` advances those queues on the
  tape's ``QUEUE_ADVANCE`` events (one per tick): waits accumulate under
  overload, and the scenario's :class:`~repro.runtime.queueing.
  ServicePolicy` (``service_policy="fifo" | "edf" | "fifo+drop" | ...``)
  decides what a saturated node drops, degrades, or turns away;
* admission runs per epoch through :class:`~repro.runtime.serve.
  AdmissionController`; with ``queue_aware_admission=True`` the controller
  prices each stream's expected queue wait (backlog at its placed node)
  into the admission bar, not just path cost.

Simulator knobs → paper sections
--------------------------------
========================  ====================================================
knob                      paper grounding
========================  ====================================================
``n_groups``/``area_m``   §III-C RPG mobility [40]; multi-group sweeps make
                          inter-group links cross ``max_range`` (ρ→0), the
                          disconnection argument of Fig. 13
``tick_s``                §III-C time-step Δt at which positions are recorded
                          and ρ(t) re-sampled via Eq. (1) (``core/radio.py``)
``epoch_ticks``           §III-C re-optimization period: OULD re-solves on the
                          fresh snapshot, OULD-MP once per epoch over the
                          predicted horizon (Eq. 14; T = epoch_ticks)
``arrival_rate_hz``       §IV "incoming requests" axis (Fig. 4–7 sweeps load;
                          here load arrives as a Poisson stream)
``hold_ticks_mean``       §III-A each request is a surveillance stream served
                          every time step until its source stops capturing
``mem_mb``/``gflops``     §IV node calibration: {256, 512} MB, 9.5 GFLOPS
``deadline_s``            §I surveillance timeliness requirement (single
                          class; ``deadline_classes`` splits the workload
                          into tiers with distinct deadlines)
``service_policy``        overload behavior of a saturated node (the
                          ``fast_mot`` skip/degrade discipline)
``mtbf_s``/``mttr_s``     §III-C "UAVs may leave the swarm" — unpredicted
                          churn, invisible to both OULD and OULD-MP horizons
========================  ====================================================

Policies are registered *planners* (see :mod:`repro.core.planner`): the
epoch loop is strategy-agnostic — it builds the richest
:class:`~repro.core.planner.TopologyView` each planner prefers (a predicted
horizon for ``ould-mp``, the fresh snapshot otherwise) and calls ``plan()``
through one :class:`~repro.runtime.serve.AdmissionController`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.events import (ChurnEvent, EventKind, EventQueue, churn_events,
                           poisson_process)
from ..core.latency import evaluate
from ..core.mobility import MultiGroupMobility, RPGParams
from ..core.ould import Problem, placement_drift
from ..core.placement import to_stages
from ..core.planner import (HorizonView, NoisyHorizonView, SnapshotView,
                            StaleView, available_planners, make_view)
from ..core.profiles import ModelProfile, lenet_profile
from ..core.radio import RadioParams, rate_matrix
from ..obs import (FRAMES, LATENCY_EDGES_S, NULL_TRACER, QUEUE,
                   MetricsRegistry)
from .queueing import (DeadlineClass, NodeQueues, PathQueues, ServicePolicy,
                       link_resource)
from .serve import AdmissionController

# Canonical registry names for the scenario matrix.
PLANNER_POLICIES = ("incremental", "incremental-sparse", "ould-mp", "nearest",
                    "hrm", "nearest-hrm")
POLICIES = PLANNER_POLICIES

MB = 1e6


@dataclasses.dataclass(frozen=True)
class SwarmScenario:
    """One time-dynamic serving scenario (defaults ≈ paper §IV, 500 m area)."""

    n_uavs: int = 10
    n_groups: int = 2
    area_m: float = 500.0
    member_radius_m: float = 25.0
    leader_speed_mps: float = 5.0
    homogeneous: bool = False      # Fig. 2a: frozen intra-group geometry
    tick_s: float = 1.0
    duration_ticks: int = 120
    epoch_ticks: int = 15
    arrival_rate_hz: float = 0.15
    hold_ticks_mean: float = 45.0
    hotspots: int = 3              # request sources live in group 0
    mem_mb_hotspot_group: float = 192.0   # scarce: forces offload
    mem_mb_other_groups: float = 512.0    # paper's high-memory level
    comp_cap_flops: float = 95e9   # 9.5 GFLOPS × 10 s decision window
    gflops: float = 9.5e9
    deadline_s: float = 1.5
    # Timeliness tiers: None ⇒ one class at ``deadline_s`` (streams draw a
    # class uniformly from the tape rng when more than one is given, so the
    # class assignment is part of the paired event tape).
    deadline_classes: tuple[DeadlineClass, ...] | None = None
    # Queue behavior of a saturated node: "<discipline>[+<overload>]", e.g.
    # "fifo", "edf", "fifo+drop", "edf+degrade:0.25", "fifo+reject"
    # (ServicePolicy.parse).  "fifo" = work-conserving, no reneging.
    service_policy: str = "fifo"
    # Epoch admission prices queue backlog (expected wait at the placed
    # node) into the bar, not just path cost (AdmissionController).
    queue_aware_admission: bool = False
    # Queueing substrate (DESIGN.md §10): "perhop" (default) queues a frame
    # at *every* server on its placed path — source uplink, each stage's
    # compute node, each boundary's directed link — so cross-traffic on
    # shared relays is priced into waits; "bottleneck" is the PR-6
    # compatibility mode (one queue at the heaviest stage's host, the rest
    # of the path deterministic), pinned bit-identical.
    queue_model: str = "perhop"
    # Drift-triggered re-placement: when set, every non-epoch tick checks
    # the kept placements' mean drift from their slack-capacity DP optimum
    # (core.ould.placement_drift) and fires an extra epoch re-solve when it
    # exceeds this many seconds (SimResult.drift_resolves counts them).
    # None (default) keeps the fixed-epoch cadence untouched.
    resolve_on_drift: float | None = None
    # Capacity-repair rule for the single-request DP's over-capacity loop
    # ("halve": the PR-1 rule, shrink the busiest node's advertised
    # capacity by 2× — can zero a node that still fit one layer; "gentle":
    # shrink to load − min hosted layer demand, excluding as little as
    # possible — admits strictly more under contention).  Default
    # unchanged so dense baselines stay pinned.
    capacity_repair: str = "halve"
    mtbf_s: float = float("inf")   # churn off by default
    mttr_s: float = 30.0
    rel_change: float = 0.05       # incremental-solver link-drift threshold
    max_path_cost_s: float = 1e6   # admission bar: reject _BIG-priced paths
    sparse_k: int | None = None    # k-candidate budget for *-sparse planners
    # Epoch re-solves place all pending requests in one jitted batch-DP
    # dispatch (core/batch_dp) — bit-identical admission, large-N speedup.
    batch_solve: bool = False
    # Degraded-view axis (ROADMAP): what the planner sees vs what serves.
    # None ⇒ the planner's preferred fresh view; "stale:<ticks>" ⇒ snapshot /
    # horizon captured that many ticks ago (StaleView); "noisy:<std>" ⇒
    # horizon rates with lognormal prediction error (NoisyHorizonView;
    # snapshot planners are unaffected — a snapshot is measured, not
    # predicted, so its degradation axis is staleness).
    view_degradation: str | None = None
    # Executed-latency sampling (repro.exec): serve latencies use measured
    # stage wall-clock (jitted apply_layers on this host) instead of the
    # analytic c_j/speed term; link delays stay priced per realized tick.
    execute: bool = False
    frame_hw: tuple[int, int, int] = (326, 595, 3)
    # Byte-moving substrate for executed mode (repro.transport): "inproc"
    # keeps the modeled-delay path; "loopback"/"multiproc" spawn worker OS
    # processes and ship each newly-seen stage-boundary activation through
    # them, so SimResult carries realized substrate bandwidth per link.
    # (Simulated radio delays still price serving — localhost sockets are
    # not a UAV link; the full rate-substitution loop is the serve CLI /
    # calibrate_rates path, where the pool IS the substrate.)
    transport: str = "inproc"
    # Persistent XLA compile cache dir (repro.exec.compile_cache): executed
    # mode's engine warms from disk — the churn-rejoin path.
    compile_cache_dir: str | None = None
    # Per-epoch slack-capacity DP lower bound (core.ould.placement_drift):
    # logs how far kept placements drifted from their per-request optimum.
    track_improvement_bound: bool = False
    radio: RadioParams = RadioParams()

    def mobility(self, seed: int) -> MultiGroupMobility:
        return MultiGroupMobility(
            RPGParams(n_uavs=self.n_uavs, area_m=self.area_m,
                      member_radius_m=self.member_radius_m,
                      leader_speed_mps=self.leader_speed_mps,
                      step_s=self.tick_s, homogeneous=self.homogeneous),
            n_groups=self.n_groups, seed=seed)

    def mem_cap(self, group_of: np.ndarray) -> np.ndarray:
        return np.where(group_of == 0, self.mem_mb_hotspot_group * MB,
                        self.mem_mb_other_groups * MB)

    def classes(self) -> tuple[DeadlineClass, ...]:
        return (self.deadline_classes
                or (DeadlineClass("standard", self.deadline_s),))


@dataclasses.dataclass(frozen=True)
class StreamRequest:
    id: int
    source: int
    arrive_tick: int
    depart_tick: int
    klass: int = 0               # index into the scenario's deadline classes


# ---------------------------------------------------------------------------
# Event tape — the frozen stochastic input every policy replays
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EventTape:
    """Everything random about one scenario run, drawn once per seed.

    Policies never touch the rng: they replay this tape, which is what makes
    per-request metrics paired across policies (and what the pairing test
    pins as data — :meth:`signature`)."""

    n_ticks: int
    tick_s: float
    epoch_ticks: int
    streams: tuple[StreamRequest, ...]
    arrival_times_s: tuple[float, ...]
    churn: tuple[ChurnEvent, ...]

    def queue(self) -> EventQueue:
        """Materialize the event queue (same-time ties pop in the insertion
        order fixed here: arrivals/departures, churn, epoch, mobility tick,
        then the tick's queue advance)."""
        q = EventQueue()
        for s, t_arr in zip(self.streams, self.arrival_times_s):
            q.push(t_arr, EventKind.ARRIVAL, s.id)
            q.push(s.depart_tick * self.tick_s, EventKind.DEPARTURE, s.id)
        for ce in self.churn:
            q.push(ce.time, ce.kind, ce.node)
        for k in range(0, self.n_ticks, self.epoch_ticks):
            q.push(k * self.tick_s, EventKind.EPOCH)
        for t in range(self.n_ticks):
            q.push(t * self.tick_s, EventKind.MOBILITY_TICK, t)
        for t in range(self.n_ticks):
            q.push(t * self.tick_s, EventKind.QUEUE_ADVANCE, t)
        return q

    def signature(self) -> dict[str, np.ndarray]:
        """The tape as arrays — two runs are paired iff these are equal."""
        return {
            "arrive_tick": np.array([s.arrive_tick for s in self.streams]),
            "depart_tick": np.array([s.depart_tick for s in self.streams]),
            "source": np.array([s.source for s in self.streams]),
            "klass": np.array([s.klass for s in self.streams]),
            "churn_time": np.array([c.time for c in self.churn]),
            "churn_node": np.array([c.node for c in self.churn]),
        }


def build_event_tape(scn: SwarmScenario, seed: int) -> EventTape:
    """Draw the scenario's full stochastic input (policy-independent)."""
    rng = np.random.default_rng(seed)
    T = scn.duration_ticks
    n_classes = len(scn.classes())
    arrivals = poisson_process(rng, scn.arrival_rate_hz, T * scn.tick_s)
    streams: list[StreamRequest] = []
    for i, t_arr in enumerate(arrivals):
        hold = max(1, int(round(rng.exponential(scn.hold_ticks_mean))))
        src = int(rng.integers(0, min(scn.hotspots, scn.n_uavs)))
        # Class draw only when tiers exist: a single-class scenario's tape
        # stays bit-identical to the pre-tier simulator.
        klass = int(rng.integers(0, n_classes)) if n_classes > 1 else 0
        at = int(t_arr / scn.tick_s)
        streams.append(StreamRequest(i, src, at, min(at + hold, T), klass))
    protected = frozenset(range(min(scn.hotspots, scn.n_uavs)))
    churn = churn_events(rng, scn.n_uavs, T * scn.tick_s, scn.mtbf_s,
                         scn.mttr_s, protected=protected)
    return EventTape(T, scn.tick_s, scn.epoch_ticks, tuple(streams),
                     tuple(float(t) for t in arrivals), tuple(churn))


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EpochLog:
    tick: int
    n_active: int
    n_admitted: int
    n_kept: int
    n_replaced: int
    solve_time_s: float
    objective: float
    feasible: bool
    n_queue_rejected: int = 0    # streams the queue-depth bar turned away
    # Improvement-bound hook (track_improvement_bound): total / worst gap
    # between kept placements and their slack-capacity DP lower bound.
    drift_total_s: float = 0.0
    drift_max_s: float = 0.0


@dataclasses.dataclass
class SimResult:
    policy: str
    n_arrivals: int
    n_never_admitted: int        # streams rejected at every epoch they lived
    served: int                  # frame serve attempts by admitted streams
    missed: int                  # over-deadline completions + outage serves
    latencies: np.ndarray        # finite realized per-frame latencies (s)
    epochs: list[EpochLog]
    outages: int = 0             # serves lost to dead nodes / faded links
    dropped: int = 0             # frames reneged by the drop policy
    degraded: int = 0            # frames served in skip/light form
    frames_rejected: int = 0     # frames turned away at the queue (reject)
    wait_total_s: float = 0.0    # total queueing delay across completions
    # (N,) offered service seconds per node over the whole run;
    # max / horizon = realized overload factor at the hottest queue
    queue_demand_s: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    # Byte-moving substrate telemetry (executed mode with a non-inproc
    # transport): realized bytes/s per sampled link, worker process pids.
    transport: str = "inproc"
    link_bytes_per_s: dict = dataclasses.field(default_factory=dict)
    warm_starts: int = 0         # churn-rejoin warm_start invocations
    drift_resolves: int = 0      # re-solves fired by resolve_on_drift
    # MetricsRegistry.snapshot() of the run: every layer's telemetry
    # (sim.* counters, queue.* tallies, solver.* aggregates, the latency
    # histogram, transport link gauges) behind one dict — DESIGN.md §9.
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def deadline_miss_rate(self) -> float:
        return self.missed / self.served if self.served else 0.0

    @property
    def over_deadline_miss_rate(self) -> float:
        """Misses that *completed* but late — ``missed`` minus outages."""
        return (self.missed - self.outages) / self.served if self.served \
            else 0.0

    @property
    def outage_rate(self) -> float:
        return self.outages / self.served if self.served else 0.0

    @property
    def loss_rate(self) -> float:
        """Frames that produced no timely decision: late completions,
        outages, policy drops, and queue rejections."""
        if not self.served:
            return 0.0
        return (self.missed + self.dropped + self.frames_rejected) / self.served

    @property
    def rejection_rate(self) -> float:
        return self.n_never_admitted / self.n_arrivals if self.n_arrivals else 0.0

    @property
    def avg_latency_s(self) -> float:
        return float(self.latencies.mean()) if self.latencies.size else float("inf")

    def _percentile(self, q: float) -> float:
        finite = self.latencies[np.isfinite(self.latencies)]
        return float(np.percentile(finite, q)) if finite.size else float("inf")

    @property
    def p50_latency_s(self) -> float:
        return self._percentile(50.0)

    @property
    def p99_latency_s(self) -> float:
        return self._percentile(99.0)

    @property
    def p999_latency_s(self) -> float:
        return self._percentile(99.9)

    @property
    def total_resolve_s(self) -> float:
        return float(sum(e.solve_time_s for e in self.epochs))

    # -- improvement-bound hook (track_improvement_bound) -------------------
    @property
    def placement_drift_s(self) -> np.ndarray:
        """Per-epoch total drift of kept placements vs their slack-capacity
        DP lower bound (zeros unless the scenario tracked the bound)."""
        return np.array([e.drift_total_s for e in self.epochs])

    @property
    def mean_placement_drift_s(self) -> float:
        d = self.placement_drift_s
        return float(d.mean()) if d.size else 0.0

    @property
    def max_placement_drift_s(self) -> float:
        return float(max((e.drift_max_s for e in self.epochs), default=0.0))


# ---------------------------------------------------------------------------
# Scalar serve references (kept as the vectorized path's ground truth)
# ---------------------------------------------------------------------------

def _masked(rates: np.ndarray, alive: np.ndarray) -> np.ndarray:
    """Zero every link touching a dead node (ρ = 0 ⇔ disconnected)."""
    if alive.all():
        return rates
    out = rates.copy()
    if out.ndim == 3:                     # (T, N, N) horizon stack
        out[:, ~alive, :] = 0.0
        out[:, :, ~alive] = 0.0
    else:
        out[~alive, :] = 0.0
        out[:, ~alive] = 0.0
    return out


def _spb(rates: np.ndarray) -> np.ndarray:
    """(N,N) realized seconds/byte of one tick's snapshot (Eq. 1 inverted;
    matches Problem.transfer_cost's bits/s convention)."""
    with np.errstate(divide="ignore"):
        s = np.where(rates > 0, 8.0 / np.maximum(rates, 1e-30), np.inf)
    np.fill_diagonal(s, 0.0)
    return s


def _serve_once(path: np.ndarray, src: int, spb_t: np.ndarray,
                alive: np.ndarray, K: list[float], Ks: float,
                comp: list[float], speed: np.ndarray) -> float:
    """Scalar reference: uncontended end-to-end latency of one frame at one
    tick (inf = outage).  The vectorized serve step must reproduce this for
    every frame when queues are empty — pinned by a test."""
    if not alive[src] or not alive[path].all():
        return float("inf")
    lat = 0.0 if path[0] == src else Ks * spb_t[src, int(path[0])]
    for j in range(len(path)):
        i = int(path[j])
        lat += comp[j] / speed[i]
        if j + 1 < len(path) and path[j + 1] != i:
            lat += K[j] * spb_t[i, int(path[j + 1])]
    return float(lat)


def _parse_degradation(spec: str | None) -> tuple[str, float] | None:
    """``"stale:3"`` / ``"noisy:0.25"`` → (mode, value)."""
    if spec is None:
        return None
    mode, _, val = spec.partition(":")
    if mode not in ("stale", "noisy"):
        raise ValueError(f"unknown view degradation {spec!r}; "
                         "use 'stale:<ticks>' or 'noisy:<std>'")
    return mode, float(val or 0.0)


def _stage_measurer(scn: SwarmScenario, profile: ModelProfile, seed: int,
                    transport=None, tracer=None):
    """Measured-seconds lookup for stage ranges: one ExecutionEngine per
    simulation, one jit + one measurement per unique (start, end) range —
    hotspot plans collapse to a handful of kernel timings.

    With ``scn.compile_cache_dir`` the engine's jit warmup goes through the
    persistent compilation cache (repeat scenarios and churn-rejoined nodes
    warm from disk).  With a byte-moving ``transport``, each newly-seen
    stage-boundary activation is additionally shipped once through a worker
    process pair, sampling the substrate's realized bandwidth at that
    payload size (SimResult.link_bytes_per_s)."""
    from ..exec import ExecutionEngine, compile_cache, layer_fns_for

    if scn.compile_cache_dir is not None:
        compile_cache.enable(scn.compile_cache_dir)
    engine = ExecutionEngine(layer_fns_for(profile), transport=transport,
                             tracer=tracer)
    rng = np.random.default_rng(seed)
    frame = rng.standard_normal((1, *scn.frame_hw)).astype(np.float32)
    acts: dict[int, object] = {0: frame}   # boundary activations, lazily
    cache: dict[tuple[int, int], float] = {}
    shipped: set[int] = set()

    def act_at(layer: int):
        if layer not in acts:
            acts[layer] = engine.closure(layer - 1, layer)(act_at(layer - 1))
        return acts[layer]

    def measure(layer_start: int, layer_end: int) -> float:
        key = (layer_start, layer_end)
        if key not in cache:
            cache[key] = engine.measure_range(layer_start, layer_end,
                                              act_at(layer_start))
            if (transport is not None and layer_start > 0
                    and layer_start not in shipped):
                shipped.add(layer_start)
                transport.ship(0, 1, act_at(layer_start))
        return cache[key]

    measure.engine = engine     # exposed for churn-rejoin warm starts
    measure.frame = frame
    return measure


# ---------------------------------------------------------------------------
# Placement table — struct-of-arrays over currently placed streams
# ---------------------------------------------------------------------------

class _PlacementTable:
    """The serve step's working set: parallel arrays over placed streams.

    Rebuilt whenever the placement dict changes (epoch re-solve, stream
    departure); between rebuilds the per-tick serve step is pure numpy
    gathers over these arrays.  Each stream's *queueing point* is the node
    hosting its heaviest stage (the compute bottleneck); ``service_s`` is
    that stage's wall and ``comp_s`` the whole path's compute, so
    ``base + service == uncontended latency`` exactly."""

    def __init__(self, comp: np.ndarray, speed: np.ndarray,
                 deadline_of: np.ndarray, measure=None,
                 k_bytes: np.ndarray | None = None, perhop: bool = False):
        self._comp = comp                    # (M,) FLOPs per layer
        self._speed = speed                  # (N,) FLOPs/s
        self._deadline_of = deadline_of      # (n_classes,) seconds
        self._measure = measure              # executed-mode stage wall lookup
        self._k_bytes = k_bytes              # (M,) boundary bytes per layer
        self._perhop = perhop                # also build full hop schedules
        self.clear()

    def clear(self) -> None:
        self.ids = np.zeros(0, np.int64)
        self.src = np.zeros(0, np.int64)
        self.path = np.zeros((0, self._comp.size), np.int64)
        self.arrive = np.zeros(0, np.int64)
        self.depart = np.zeros(0, np.int64)
        self.deadline_s = np.zeros(0)
        self.q_node = np.zeros(0, np.int64)
        self.service_s = np.zeros(0)
        self.comp_s = np.zeros(0)
        # Hop schedule (perhop mode): per stream, the ordered stages of its
        # placed path — stage_node[s, k] hosts stage k for stage_wall[s, k]
        # seconds, bound_bytes[s, k] bytes cross the (k → k+1) boundary.
        # -1 / 0 pad rows with fewer stages.
        self.stage_node = np.zeros((0, 1), np.int64)
        self.stage_wall = np.zeros((0, 1))
        self.bound_bytes = np.zeros((0, 1))

    def rebuild(self, placed: dict[int, np.ndarray],
                streams: dict[int, "StreamRequest"]) -> None:
        ids = sorted(placed)
        S, M = len(ids), self._comp.size
        self.ids = np.array(ids, np.int64)
        self.path = (np.stack([placed[i] for i in ids])
                     if ids else np.zeros((0, M), np.int64))
        self.src = np.array([streams[i].source for i in ids], np.int64)
        self.arrive = np.array([streams[i].arrive_tick for i in ids],
                               np.int64)
        self.depart = np.array([streams[i].depart_tick for i in ids],
                               np.int64)
        self.deadline_s = self._deadline_of[
            np.array([streams[i].klass for i in ids], np.int64)] \
            if ids else np.zeros(0)
        if not ids:
            self.q_node = np.zeros(0, np.int64)
            self.service_s = np.zeros(0)
            self.comp_s = np.zeros(0)
            self.stage_node = np.zeros((0, 1), np.int64)
            self.stage_wall = np.zeros((0, 1))
            self.bound_bytes = np.zeros((0, 1))
            return
        if self._measure is None:
            per_layer = self._comp[None, :] / self._speed[self.path]
            rows = np.arange(S)[:, None]
            stage_id = np.zeros((S, M), np.int64)
            stage_id[:, 1:] = np.cumsum(self.path[:, 1:] != self.path[:, :-1],
                                        axis=1)
            stage_sum = np.zeros((S, M))
            np.add.at(stage_sum, (np.broadcast_to(rows, (S, M)), stage_id),
                      per_layer)
            per_layer_stage = stage_sum[np.broadcast_to(rows, (S, M)),
                                        stage_id]
            j_star = np.argmax(per_layer_stage, axis=1)
            self.service_s = per_layer_stage[np.arange(S), j_star]
            self.q_node = self.path[np.arange(S), j_star]
            self.comp_s = per_layer.sum(axis=1)
            if self._perhop:
                rows_b = np.broadcast_to(rows, (S, M))
                s_max = int(stage_id[:, -1].max()) + 1
                sn = np.full((S, s_max), -1, np.int64)
                sn[rows_b, stage_id] = self.path
                # Same np.add.at accumulation order as stage_sum above, so
                # stage walls are float-identical to the bottleneck table's.
                sw = np.zeros((S, s_max))
                np.add.at(sw, (rows_b, stage_id), per_layer)
                bb = np.zeros((S, s_max))
                b_mask = self.path[:, 1:] != self.path[:, :-1]
                bb[rows_b[:, :-1][b_mask], stage_id[:, :-1][b_mask]] = \
                    np.broadcast_to(self._k_bytes[None, :-1],
                                    (S, M - 1))[b_mask]
                self.stage_node, self.stage_wall = sn, sw
                self.bound_bytes = bb
        else:                               # executed mode: measured walls
            q_node = np.zeros(S, np.int64)
            service = np.zeros(S)
            comp_s = np.zeros(S)
            stage_rows = []
            for row in range(S):
                stages = to_stages(self.path[row])
                walls = [(self._measure(st.layer_start, st.layer_end),
                          st.node) for st in stages]
                comp_s[row] = sum(w for w, _ in walls)
                service[row], q_node[row] = max(walls)
                stage_rows.append([(st.node, w, st.layer_end)
                                   for (w, _), st in zip(walls, stages)])
            self.q_node, self.service_s, self.comp_s = q_node, service, comp_s
            if self._perhop:
                s_max = max(len(sr) for sr in stage_rows)
                sn = np.full((S, s_max), -1, np.int64)
                sw = np.zeros((S, s_max))
                bb = np.zeros((S, s_max))
                for row, sr in enumerate(stage_rows):
                    for k, (node, wall, layer_end) in enumerate(sr):
                        sn[row, k] = node
                        sw[row, k] = wall
                        if k + 1 < len(sr):
                            bb[row, k] = self._k_bytes[layer_end - 1]
                self.stage_node, self.stage_wall = sn, sw
                self.bound_bytes = bb

    def active_rows(self, tick: int) -> np.ndarray:
        return np.flatnonzero((self.arrive <= tick) & (tick < self.depart))


# ---------------------------------------------------------------------------
# The simulation — tape replay over the layered runtime
# ---------------------------------------------------------------------------

class _Simulation:
    """One policy replaying one tape: epoch loop (admission + placement),
    vectorized serve step (frame emission), and queue advance (completion
    accounting) — the decomposed form of the old monolithic ``simulate``."""

    def __init__(self, scn: SwarmScenario, policy: str, seed: int,
                 profile: ModelProfile, cold_resolves: bool, tracer=None):
        if policy not in available_planners():
            raise ValueError(f"unknown policy {policy!r}; one of "
                             f"{available_planners()}")
        self.scn = scn
        # Observability: NullTracer by default (traced-off path bit-identical
        # — every emit below is guarded by ``trace.enabled``); the registry
        # is filled once at end of run from the layers' own counters.
        self.trace = tracer if tracer is not None else NULL_TRACER
        self.metrics = MetricsRegistry()
        self._churn_track = (self.trace.track("churn")
                             if self.trace.enabled else -1)
        if self.trace.enabled:
            self.trace.intern("frame", "base_s", "service_s")
        self.policy = policy
        self.seed = seed
        self.profile = profile
        self.tape = build_event_tape(scn, seed)
        self.streams = {s.id: s for s in self.tape.streams}

        mob = scn.mobility(seed)
        T = scn.duration_ticks
        pos = mob.positions(T, seed=seed + 1)
        self.rates_t = [rate_matrix(pos[t], scn.radio) for t in range(T)]
        self.mem_cap = scn.mem_cap(mob.group_of)
        self.comp_cap = np.full(scn.n_uavs, scn.comp_cap_flops)
        self.speed = np.full(scn.n_uavs, scn.gflops)
        self.K = np.asarray(profile.output_vector())
        self.Ks = profile.input_bytes
        self.comp = np.asarray(profile.compute_vector())
        self.deadline_of = np.array([c.deadline_s for c in scn.classes()])

        if scn.queue_model not in ("perhop", "bottleneck"):
            raise ValueError(f"unknown queue_model {scn.queue_model!r}; "
                             "one of ('perhop', 'bottleneck')")
        self.perhop = scn.queue_model == "perhop"
        self.ctrl = AdmissionController(policy, solver="dp",
                                        warm=not cold_resolves,
                                        rel_change=scn.rel_change,
                                        max_path_cost=scn.max_path_cost_s,
                                        sparse_k=scn.sparse_k,
                                        batch_solve=scn.batch_solve,
                                        capacity_repair=scn.capacity_repair,
                                        tracer=self.trace,
                                        queue_model=scn.queue_model)
        self.wants_horizon = getattr(self.ctrl.planner, "preferred_view",
                                     "snapshot") == "horizon"
        self.degradation = _parse_degradation(scn.view_degradation)
        self.transport = None
        if scn.execute and scn.transport != "inproc":
            from ..transport import make_transport
            self.transport = make_transport(scn.transport,
                                            group_of=mob.group_of)
        measure = (_stage_measurer(scn, profile, seed,
                                   transport=self.transport,
                                   tracer=self.trace)
                   if scn.execute else None)
        self.measure = measure
        self.warm_starts = 0         # churn-rejoin warm_start invocations
        self.table = _PlacementTable(self.comp, self.speed, self.deadline_of,
                                     measure, k_bytes=self.K,
                                     perhop=self.perhop)
        queues_cls = PathQueues if self.perhop else NodeQueues
        self.queues = queues_cls(scn.n_uavs,
                                 ServicePolicy.parse(scn.service_policy))

        # mutable run state
        self.alive = np.ones(scn.n_uavs, bool)
        self.active: dict[int, StreamRequest] = {}
        self.placed: dict[int, np.ndarray] = {}
        self.ever_admitted: set[int] = set()
        self._dirty = False                  # placement arrays need rebuild
        self._pending: dict | None = None    # this tick's emitted frames
        self.epochs: list[EpochLog] = []
        self._lat_chunks: list[np.ndarray] = []
        self.served = self.missed = self.outages = 0
        self.dropped = self.degraded = self.frames_rejected = 0
        self.wait_total_s = 0.0
        self._solver_jit_compiles = 0
        self.drift_resolves = 0

    # -- epoch layer --------------------------------------------------------
    def _build_view(self, tick: int):
        """The planner's view of the network at this epoch — fresh by
        default, degraded when the scenario asks (serving always happens on
        the realized per-tick rates, so the gap is measured, not assumed)."""
        scn, T = self.scn, self.scn.duration_ticks
        stale = 0
        if self.degradation is not None and self.degradation[0] == "stale":
            stale = int(self.degradation[1])
        seen = max(0, tick - stale)
        if self.wants_horizon:  # the epoch's predicted rates (Eq. 14 horizon)
            end = min(seen + scn.epoch_ticks, T)
            view = HorizonView(np.stack(self.rates_t[seen:end]),
                               self.alive.copy())
            if self.degradation is not None and self.degradation[0] == "noisy":
                view = NoisyHorizonView.corrupt(
                    view, self.degradation[1],
                    seed=self.seed * 100003 + tick)
            return view
        if stale:
            return StaleView(self.rates_t[seen], self.alive.copy(),
                             age_ticks=stale)
        return make_view(self.rates_t[tick], self.alive.copy())

    def on_epoch(self, tick: int) -> None:
        scn = self.scn
        act = sorted(self.active.values(), key=lambda s: s.id)
        self.placed = {}
        self._dirty = True
        if not act:
            self.epochs.append(EpochLog(tick, 0, 0, 0, 0, 0.0, 0.0, True))
            return
        sources = np.array([s.source for s in act], np.int64)
        ids = [s.id for s in act]
        view = self._build_view(tick)
        backlog = (self.queues.backlog_s(tick * scn.tick_s)
                   if scn.queue_aware_admission else None)
        deadline_s = self.deadline_of[np.array([s.klass for s in act])]
        plan = self.ctrl.admit(
            Problem(self.profile, self.mem_cap, self.comp_cap, view.rates,
                    sources, self.speed), view, request_ids=ids,
            backlog_s=backlog, deadline_s=deadline_s,
            now_s=tick * scn.tick_s)
        stats = plan.solve_stats
        n_kept = stats.n_kept if stats is not None else 0
        n_rep = stats.n_replaced if stats is not None else len(act)
        if stats is not None:
            self._solver_jit_compiles += stats.n_jit_compiles
        for row, s in enumerate(act):
            if plan.admitted[row]:
                self.placed[s.id] = plan.assign[row]
                self.ever_admitted.add(s.id)
        # capacity invariant under the *snapshot* problem (Eq. 4/5)
        feas_prob = SnapshotView(self.rates_t[tick], self.alive.copy()).bind(
            Problem(self.profile, self.mem_cap, self.comp_cap,
                    self.rates_t[tick], sources, self.speed))
        ev = evaluate(feas_prob, plan.solution)
        drift_total = drift_max = 0.0
        if scn.track_improvement_bound and plan.n_admitted:
            # How far do kept placements drift from each request's own
            # slack-capacity optimum, judged on the *realized* snapshot?
            drift = placement_drift(feas_prob, plan.assign, plan.admitted,
                                    sparse_k=scn.sparse_k)
            drift_total = float(drift.sum())
            drift_max = float(drift.max())
        self.epochs.append(EpochLog(
            tick, len(act), plan.n_admitted, n_kept, n_rep,
            plan.solve_time_s, plan.objective, ev.feasible,
            self.ctrl.last_queue_rejected,
            drift_total_s=drift_total, drift_max_s=drift_max))

    def _maybe_drift_resolve(self, t: int) -> None:
        """Drift-triggered re-placement (``resolve_on_drift``): on non-epoch
        ticks, re-solve early when the kept placements' mean drift from
        their slack-capacity DP optimum (judged on the realized snapshot)
        exceeds the threshold — the improvement-bound hook promoted from
        measuring the keep rule's cost to acting on it."""
        scn = self.scn
        if (scn.resolve_on_drift is None or not self.placed
                or t % scn.epoch_ticks == 0):
            return
        ids = sorted(self.placed)
        assign = np.stack([self.placed[i] for i in ids])
        sources = np.array([self.streams[i].source for i in ids], np.int64)
        prob = SnapshotView(self.rates_t[t], self.alive.copy()).bind(
            Problem(self.profile, self.mem_cap, self.comp_cap,
                    self.rates_t[t], sources, self.speed))
        drift = placement_drift(prob, assign, np.ones(len(ids), bool),
                                sparse_k=scn.sparse_k)
        if float(drift.mean()) > scn.resolve_on_drift:
            self.drift_resolves += 1
            self.on_epoch(t)

    # -- serve layer (vectorized frame emission) ----------------------------
    def on_tick(self, t: int) -> None:
        self._maybe_drift_resolve(t)
        if self._dirty:
            self.table.rebuild(self.placed, self.streams)
            self._dirty = False
        rows = self.table.active_rows(t)
        if rows.size == 0:
            return
        if self.perhop:
            self._on_tick_perhop(t, rows)
            return
        tab, K, Ks = self.table, self.K, self.Ks
        spb_t = _spb(_masked(self.rates_t[t], self.alive))
        src, path = tab.src[rows], tab.path[rows]
        outage = ~self.alive[src] | (~self.alive[path]).any(axis=1)

        first = path[:, 0]
        with np.errstate(invalid="ignore"):
            link_s = np.where(first == src, 0.0, Ks * spb_t[src, first])
            for j in range(path.shape[1] - 1):
                a, b = path[:, j], path[:, j + 1]
                link_s = link_s + np.where(a == b, 0.0, K[j] * spb_t[a, b])
        outage |= ~np.isfinite(link_s)

        self.served += rows.size
        n_out = int(outage.sum())
        self.outages += n_out
        self.missed += n_out                 # inf > any deadline
        if self.trace.enabled and n_out:
            self.trace.instant_batch(
                FRAMES, "outage", np.full(n_out, t * self.scn.tick_s),
                lane=src[outage], frame=tab.ids[rows[outage]])
        ok = ~outage
        if not ok.any():
            return
        r = rows[ok]
        arrival = np.full(r.size, t * self.scn.tick_s)
        # base excludes the bottleneck stage: the queue adds it back as the
        # frame's service (possibly degraded), so base + service == the
        # scalar reference exactly when queues are empty.
        base = link_s[ok] + tab.comp_s[r] - tab.service_s[r]
        self._pending = {
            "node": tab.q_node[r], "arrival": arrival,
            "service": tab.service_s[r],
            "deadline_abs": arrival + tab.deadline_s[r],
            "base": base,
        }
        if self.trace.enabled:
            self._pending["ids"] = tab.ids[r]

    def _on_tick_perhop(self, t: int, rows: np.ndarray) -> None:
        """Per-hop frame emission: instead of one ``(base, service)`` pair,
        each frame carries its full hop schedule — source uplink, each
        stage's compute server, each boundary's directed link — resources
        and services aligned as ``(F, 2·S_max)`` arrays for the tandem
        kernel (hop 0 = uplink; hop 2k+1 = stage k; hop 2k+2 = boundary
        k → k+1; ``res = -1`` pads)."""
        tab, Ks, scn = self.table, self.Ks, self.scn
        n = scn.n_uavs
        spb_t = _spb(_masked(self.rates_t[t], self.alive))
        src, path = tab.src[rows], tab.path[rows]
        outage = ~self.alive[src] | (~self.alive[path]).any(axis=1)

        sn = tab.stage_node[rows]
        sw = tab.stage_wall[rows]
        bb = tab.bound_bytes[rows]
        n_frames, s_max = sn.shape
        res = np.full((n_frames, 2 * s_max), -1, np.int64)
        svc = np.zeros((n_frames, 2 * s_max))
        first = sn[:, 0]
        has_up = first != src
        with np.errstate(invalid="ignore"):
            up_s = np.where(has_up, Ks * spb_t[src, first], 0.0)
            res[:, 0] = np.where(has_up, link_resource(n, src, first), -1)
            svc[:, 0] = up_s
            link_bad = ~np.isfinite(up_s)
            for k in range(s_max):
                node = sn[:, k]
                valid = node >= 0
                res[:, 2 * k + 1] = np.where(valid, node, -1)
                svc[:, 2 * k + 1] = np.where(valid, sw[:, k], 0.0)
                if k + 1 < s_max:
                    nxt = sn[:, k + 1]
                    hop_ok = nxt >= 0
                    a = np.where(valid, node, 0)
                    b = np.where(hop_ok, nxt, 0)
                    l_s = np.where(hop_ok, bb[:, k] * spb_t[a, b], 0.0)
                    res[:, 2 * k + 2] = np.where(hop_ok,
                                                 link_resource(n, a, b), -1)
                    svc[:, 2 * k + 2] = l_s
                    link_bad |= ~np.isfinite(l_s)
        outage |= link_bad

        self.served += rows.size
        n_out = int(outage.sum())
        self.outages += n_out
        self.missed += n_out                 # inf > any deadline
        if self.trace.enabled and n_out:
            self.trace.instant_batch(
                FRAMES, "outage", np.full(n_out, t * scn.tick_s),
                lane=src[outage], frame=tab.ids[rows[outage]])
        ok = ~outage
        if not ok.any():
            return
        r = rows[ok]
        arrival = np.full(r.size, t * scn.tick_s)
        self._pending = {
            "res": res[ok], "svc": svc[ok], "arrival": arrival,
            "deadline_abs": arrival + tab.deadline_s[r],
            "node": tab.q_node[r],
        }
        if self.trace.enabled:
            self._pending["ids"] = tab.ids[r]

    # -- queue layer (completion accounting) --------------------------------
    def on_queue_advance(self, t: int) -> None:
        if self._pending is None:
            return
        p, self._pending = self._pending, None
        if self.perhop:
            out = self.queues.advance(p["res"], p["svc"], p["arrival"],
                                      p["deadline_abs"])
            self.dropped += int(out.dropped.sum())
            self.frames_rejected += int(out.rejected.sum())
            self.degraded += int(out.degraded.sum())
            done = out.completed
            if done.any():
                lat = out.lat_s[done]
                self.wait_total_s += float(out.wait_total_s[done].sum())
                self.missed += int((lat > p["deadline_abs"][done]
                                    - p["arrival"][done]).sum())
                finite = lat[np.isfinite(lat)]
                if finite.size:
                    self._lat_chunks.append(finite)
            if self.trace.enabled:
                self._trace_path_outcome(p, out)
            return
        out = self.queues.advance(p["node"], p["arrival"], p["service"],
                                  p["deadline_abs"])
        self.dropped += int(out.dropped.sum())
        self.frames_rejected += int(out.rejected.sum())
        self.degraded += int(out.degraded.sum())
        done = out.completed
        lat = None
        if done.any():
            lat = (p["base"][done] + out.wait_s[done]
                   + out.service_used_s[done])
            self.wait_total_s += float(out.wait_s[done].sum())
            self.missed += int((lat > p["deadline_abs"][done]
                                - p["arrival"][done]).sum())
            finite = lat[np.isfinite(lat)]
            if finite.size:
                self._lat_chunks.append(finite)
        if self.trace.enabled:
            self._trace_queue_outcome(p, out, lat)

    def _trace_queue_outcome(self, p: dict, out, lat) -> None:
        """Rebuild this window's per-frame spans from the Lindley kernel
        outputs — post-hoc and vectorized, never inside the kernel
        (DESIGN.md §9).  Span algebra the audit test pins:
        ``frame.dur == base_s + queue_wait.dur + service.dur``."""
        tr, ids, node, arr = self.trace, p["ids"], p["node"], p["arrival"]
        done = out.completed
        if lat is not None:
            a, ln, fr = arr[done], node[done], ids[done]
            sv = out.service_used_s[done]
            tr.span_batch(QUEUE, "queue_wait", a, out.wait_s[done],
                          lane=ln, frame=fr)
            tr.span_batch(QUEUE, "service", out.start_s[done], sv,
                          lane=ln, frame=fr)
            tr.span_batch(FRAMES, "frame", a, lat, lane=ln, frame=fr,
                          a0=p["base"][done], a1=sv)
        for name, mask in (("drop", out.dropped),
                           ("reject_queue", out.rejected)):
            if mask.any():
                tr.instant_batch(FRAMES, name, arr[mask], lane=node[mask],
                                 frame=ids[mask])

    def _trace_path_outcome(self, p: dict, out) -> None:
        """Per-hop spans reconstructed post hoc from the tandem kernel
        outputs (DESIGN.md §10): every real hop of a completed frame emits
        a ``hop_wait`` span (previous hop's finish → this hop's service
        start) plus a ``hop_service`` (compute hop) or ``link`` (transfer
        hop) span.  Audit algebra: ``frame.dur == Σ hop_wait.dur +
        Σ hop_service.dur + Σ link.dur`` per frame id."""
        tr, ids, arr = self.trace, p["ids"], p["arrival"]
        res, node = p["res"], p["node"]
        done = out.completed
        n = self.scn.n_uavs
        if done.any():
            tr.span_batch(FRAMES, "frame", arr[done], out.lat_s[done],
                          lane=node[done], frame=ids[done],
                          a0=out.wait_total_s[done],
                          a1=out.lat_s[done] - out.wait_total_s[done])
            for h in range(res.shape[1]):
                real = done & (res[:, h] >= 0)
                if not real.any():
                    continue
                is_link = real & (res[:, h] >= n)
                is_node = real & ~is_link
                st = out.start_s[:, h]
                w = out.wait_s[:, h]
                sv = out.service_used_s[:, h]
                tr.span_batch(QUEUE, "hop_wait", st[real] - w[real],
                              w[real], lane=res[real, h], frame=ids[real])
                if is_node.any():
                    tr.span_batch(QUEUE, "hop_service", st[is_node],
                                  sv[is_node], lane=res[is_node, h],
                                  frame=ids[is_node])
                if is_link.any():
                    tr.span_batch(QUEUE, "link", st[is_link], sv[is_link],
                                  lane=res[is_link, h] - n,
                                  frame=ids[is_link])
        for name, mask in (("drop", out.dropped),
                           ("reject_queue", out.rejected)):
            if mask.any():
                tr.instant_batch(FRAMES, name, arr[mask], lane=node[mask],
                                 frame=ids[mask])

    def _warm_rejoin(self) -> None:
        """Pre-compile the live plan's stage signature on churn rejoin.

        A node that rejoins mid-scenario will be handed stages from the
        next epoch's plan; the distinct ``(layer_start, layer_end)`` ranges
        of the *current* placements are the best predictor of that
        signature, and with the persistent compile cache enabled the
        warm-up replays as disk hits — milliseconds, off the serving clock
        (ExecutionEngine.warm_start; executed mode only)."""
        if self.measure is None or not self.placed:
            return
        sig = {(st.layer_start, st.layer_end)
               for path in self.placed.values() for st in to_stages(path)}
        self.measure.engine.warm_start(sorted(sig), self.measure.frame[0])
        self.warm_starts += 1

    # -- driver -------------------------------------------------------------
    def run(self) -> SimResult:
        try:
            return self._run()
        finally:
            if self.transport is not None:
                self.transport.close()

    def _run(self) -> SimResult:
        q = self.tape.queue()
        while q:
            ev = q.pop()
            if ev.kind == EventKind.ARRIVAL:
                self.active[ev.payload] = self.streams[ev.payload]
                if self.trace.enabled:
                    self.trace.instant(
                        FRAMES, "arrival", ev.time,
                        lane=self.streams[ev.payload].source,
                        frame=ev.payload)
            elif ev.kind == EventKind.DEPARTURE:
                self.active.pop(ev.payload, None)
                if self.placed.pop(ev.payload, None) is not None:
                    self._dirty = True
            elif ev.kind == EventKind.NODE_FAIL:
                self.alive[ev.payload] = False
                if self.trace.enabled:
                    self.trace.instant(self._churn_track, "node_fail",
                                       ev.time, lane=ev.payload)
            elif ev.kind == EventKind.NODE_REJOIN:
                self.alive[ev.payload] = True
                if self.trace.enabled:
                    self.trace.instant(self._churn_track, "node_rejoin",
                                       ev.time, lane=ev.payload)
                self._warm_rejoin()
            elif ev.kind == EventKind.EPOCH:
                self.on_epoch(int(round(ev.time / self.scn.tick_s)))
            elif ev.kind == EventKind.MOBILITY_TICK:
                self.on_tick(ev.payload)
            elif ev.kind == EventKind.QUEUE_ADVANCE:
                self.on_queue_advance(ev.payload)
        lats = (np.concatenate(self._lat_chunks) if self._lat_chunks
                else np.zeros(0))
        n_never = sum(1 for s in self.streams.values()
                      if s.id not in self.ever_admitted)
        link_bw = ({k: ls.bytes_per_s
                    for k, ls in self.transport.link_stats.items()}
                   if self.transport is not None else {})
        self._fill_metrics(lats, link_bw)
        return SimResult(self.policy, len(self.streams), n_never,
                         self.served, self.missed, lats, self.epochs,
                         outages=self.outages, dropped=self.dropped,
                         degraded=self.degraded,
                         frames_rejected=self.frames_rejected,
                         wait_total_s=self.wait_total_s,
                         queue_demand_s=self.queues.demand_s.copy(),
                         transport=self.scn.transport if self.scn.execute
                         else "inproc",
                         link_bytes_per_s=link_bw,
                         warm_starts=self.warm_starts,
                         drift_resolves=self.drift_resolves,
                         metrics=self.metrics.snapshot())

    def _fill_metrics(self, lats: np.ndarray, link_bw: dict) -> None:
        """Fold every layer's private run telemetry into the registry —
        the one ``snapshot()`` SimResult/bench/CLI report (DESIGN.md §9).
        Filled once at end of run from counters the layers kept anyway, so
        the per-tick hot path is untouched."""
        m = self.metrics
        for name, v in (("sim.arrivals", len(self.streams)),
                        ("sim.served", self.served),
                        ("sim.missed", self.missed),
                        ("sim.outages", self.outages),
                        ("sim.dropped", self.dropped),
                        ("sim.degraded", self.degraded),
                        ("sim.frames_rejected", self.frames_rejected),
                        ("sim.completions", int(lats.size)),
                        ("solver.epochs", len(self.epochs)),
                        ("solver.n_kept",
                         sum(e.n_kept for e in self.epochs)),
                        ("solver.n_replaced",
                         sum(e.n_replaced for e in self.epochs)),
                        ("solver.queue_rejected",
                         sum(e.n_queue_rejected for e in self.epochs)),
                        ("solver.jit_compiles", self._solver_jit_compiles),
                        ("solver.warm_starts", self.warm_starts),
                        ("solver.drift_resolves", self.drift_resolves)):
            m.counter(name).inc(v)
        m.gauge("sim.wait_total_s").set(self.wait_total_s)
        m.gauge("solver.total_solve_s").set(
            float(sum(e.solve_time_s for e in self.epochs)))
        for name, v in self.queues.snapshot().items():
            if isinstance(v, float):
                m.gauge(name).set(v)
            else:
                m.counter(name).inc(v)
        m.histogram("sim.latency_s", LATENCY_EDGES_S).observe_many(lats)
        for link, bps in link_bw.items():
            m.gauge(f"transport.link.{link}.bytes_per_s").set(float(bps))
        if self.trace.enabled:
            m.gauge("trace.n_events").set(self.trace.n_events)
            m.gauge("trace.n_dropped").set(self.trace.n_dropped)


def simulate(scn: SwarmScenario, policy: str, seed: int = 0, *,
             profile: ModelProfile | None = None,
             cold_resolves: bool = False, tracer=None) -> SimResult:
    """Run one policy over the scenario's event tape.

    ``cold_resolves=True`` forces every epoch re-solve from scratch (the
    baseline the warm-started incremental path is measured against); it only
    affects solve *time*, never the event tape.

    ``tracer`` is an optional :class:`repro.obs.Tracer`: per-frame spans are
    reconstructed from the queue kernel outputs onto it (timestamps in
    *simulated* seconds), plus solver/admission/churn events; ``None`` keeps
    the NullTracer default — the traced-off serving path is bit-identical.
    """
    return _Simulation(scn, policy, seed, profile or lenet_profile(),
                       cold_resolves, tracer).run()


def compare_policies(scn: SwarmScenario, seed: int = 0,
                     policies=POLICIES,
                     profile: ModelProfile | None = None) -> dict[str, SimResult]:
    """Run every policy over the SAME event tape (paired comparison)."""
    return {p: simulate(scn, p, seed, profile=profile) for p in policies}


def warm_vs_cold(scn: SwarmScenario, seed: int = 0,
                 profile: ModelProfile | None = None) -> dict:
    """Measure what the incremental solver buys: identical OULD runs, one
    with warm epoch re-solves, one forced cold.  The event tape and placement
    *decisions* may only differ where the warm path keeps a placement the
    cold solve would recompute identically — the objective ratio reports any
    drift."""
    warm = simulate(scn, "incremental", seed, profile=profile,
                    cold_resolves=False)
    cold = simulate(scn, "incremental", seed, profile=profile,
                    cold_resolves=True)
    ratios = [w.objective / c.objective
              for w, c in zip(warm.epochs, cold.epochs)
              if c.objective > 0 and np.isfinite(c.objective)]
    return {
        "warm_solve_s": warm.total_resolve_s,
        "cold_solve_s": cold.total_resolve_s,
        "speedup": (cold.total_resolve_s / warm.total_resolve_s
                    if warm.total_resolve_s > 0 else float("inf")),
        "objective_ratio_max": max(ratios) if ratios else 1.0,
        "warm": warm,
        "cold": cold,
    }
