from . import elastic, queueing, serve, steps, swarm, train_loop
from .steps import (TrainConfig, init_opt_state, make_decode_step,
                    make_prefill_step, make_train_step)

__all__ = ["TrainConfig", "elastic", "init_opt_state", "make_decode_step",
           "make_prefill_step", "make_train_step", "queueing", "serve",
           "steps", "swarm", "train_loop"]
