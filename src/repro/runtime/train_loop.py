"""Fault-tolerant training loop.

Production behaviours implemented here (exercised by tests/examples on CPU,
designed for multi-host):

* **checkpoint/restart** — async checkpoints every ``ckpt_every`` steps with
  the data-pipeline cursor saved alongside; ``run()`` auto-resumes from the
  latest checkpoint (exact: the synthetic pipeline is a pure function of
  (seed, step)).
* **node-failure handling** — a ``FailureInjector`` (tests) or a real
  preemption raises mid-step; the loop restores the last checkpoint and, if
  the device set changed, re-shards via CheckpointManager.restore(shardings=)
  onto the surviving mesh (elastic.py chooses the new mesh/batch split).
* **straggler mitigation** — per-step wall times feed an EWMA detector; on a
  sustained straggler the loop calls the elastic re-plan hook (on TPU this
  re-solves OULD with the degraded node's compute capacity — the paper's
  technique as the re-placement engine; see elastic.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from ..checkpointing import AsyncCheckpointer, CheckpointManager
from ..configs.base import ModelConfig
from ..data import DataConfig, DataLoader
from ..models import transformer
from . import steps as steps_mod


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_ewma: float = 0.9
    straggler_factor: float = 3.0   # step > factor × EWMA ⇒ straggler event


class StragglerDetector:
    def __init__(self, cfg: LoopConfig):
        self.cfg = cfg
        self.ewma: float | None = None
        self.events: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.cfg.straggler_factor * self.ewma
        self.ewma = (self.cfg.straggler_ewma * self.ewma
                     + (1 - self.cfg.straggler_ewma) * dt)
        if is_straggler:
            self.events.append(step)
        return is_straggler


def run(cfg: ModelConfig, tcfg: steps_mod.TrainConfig, lcfg: LoopConfig,
        dcfg: DataConfig, *, seed: int = 0,
        fail_at: Callable[[int], bool] | None = None,
        on_straggler: Callable[[int], None] | None = None,
        params: Any = None) -> dict:
    """Train with auto-resume.  Returns summary metrics.  ``fail_at(step)``
    lets tests inject a crash; the outer retry below plays the role of the
    cluster scheduler restarting the job."""
    mgr = CheckpointManager(lcfg.ckpt_dir, keep=lcfg.keep)
    ckpt = AsyncCheckpointer(mgr)
    train_step = jax.jit(steps_mod.make_train_step(cfg, tcfg),
                         donate_argnums=(0, 1))

    if params is None:
        params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = steps_mod.init_opt_state(params, tcfg)
    start_step = 0

    latest = mgr.latest_step()
    if latest is not None:  # resume
        (params, opt_state), extra = mgr.restore(
            latest, (params, opt_state))
        start_step = int(extra["next_step"])

    loader = DataLoader(dcfg, start_step=start_step)
    detector = StragglerDetector(lcfg)
    losses: list[float] = []
    step = start_step
    try:
        for step in range(start_step, lcfg.total_steps):
            batch = next(loader)
            if fail_at is not None and fail_at(step):
                raise RuntimeError(f"injected node failure at step {step}")
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if detector.observe(step, dt) and on_straggler is not None:
                on_straggler(step)
            losses.append(float(metrics["loss"]))
            if (step + 1) % lcfg.ckpt_every == 0:
                ckpt.save(step, (params, opt_state),
                          extra={"next_step": step + 1,
                                 "loss": losses[-1]})
        ckpt.save(lcfg.total_steps - 1, (params, opt_state),
                  extra={"next_step": lcfg.total_steps,
                         "loss": losses[-1] if losses else float("nan")})
    finally:
        ckpt.wait()
        loader.close()
    return {"losses": losses, "last_step": step,
            "straggler_events": detector.events,
            "params": params, "opt_state": opt_state}


def run_with_restarts(cfg, tcfg, lcfg, dcfg, *, max_restarts: int = 3,
                      fail_at=None, **kw) -> dict:
    """The cluster-scheduler wrapper: restart-on-failure up to N times.
    Each restart resumes from the latest atomic checkpoint."""
    attempts = 0
    while True:
        try:
            out = run(cfg, tcfg, lcfg, dcfg, fail_at=fail_at, **kw)
            out["restarts"] = attempts
            return out
        except RuntimeError:
            attempts += 1
            if attempts > max_restarts:
                raise
