"""jit-able step functions: train / prefill / decode.

These are the units the launcher jits (with shardings) and the dry-run
lowers.  Pure functions of (params, opt_state, batch) — donation and
sharding are applied at the jit boundary in ``launch/``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from ..configs.base import ModelConfig
from ..models import transformer
from ..optim import adamw
from ..optim import compression as comp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    remat: bool = True
    grad_compression: bool = False   # int8 EF compression (cross-pod traffic)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    def train_step(params: Any, opt_state: dict, batch: dict
                   ) -> tuple[Any, dict, dict]:
        def loss_of(p):
            return transformer.loss_fn(p, cfg, batch, remat=tcfg.remat)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
        if tcfg.grad_compression:
            grads, new_err = comp.compress_with_feedback(
                grads, opt_state["comp_error"])
        new_params, new_opt, opt_metrics = adamw.update(
            tcfg.optimizer, grads, opt_state, params)
        if tcfg.grad_compression:
            new_opt["comp_error"] = new_err
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def init_opt_state(params: Any, tcfg: TrainConfig) -> dict:
    state = adamw.init(params)
    if tcfg.grad_compression:
        state["comp_error"] = comp.init_error(params)
    return state


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    def prefill_step(params: Any, batch: dict) -> tuple[jax.Array, list]:
        return transformer.prefill(params, cfg, batch, max_len=max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params: Any, tokens: jax.Array, cache: list,
                    pos: jax.Array) -> tuple[jax.Array, list]:
        return transformer.decode_step(params, cfg, tokens, cache, pos)

    return decode_step
