"""Per-node request queues: the always-on serving substrate.

The simulator used to serve every frame *within* its tick — a node could
absorb unlimited work per time step, so overload, head-of-line blocking and
tail latency were unobservable.  This module is the layer where a saturated
node exists: each frame occupies its placed node's queue for its
measured/modeled stage wall, waits behind earlier frames, and under overload
the :class:`ServicePolicy` decides what to drop, degrade, or turn away (the
``fast_mot`` skip/degrade discipline: a real-time tracker that falls behind
skips the expensive detector rather than queueing into uselessness).

Everything is struct-of-arrays over frames — numpy arrays for node id,
arrival time, service demand and absolute deadline — so scenarios with
10⁵–10⁶ frames advance through a handful of vectorized kernels instead of a
Python event loop:

* :func:`fifo_advance_kernel` — the vectorized queue-advance kernel: one
  segmented Lindley recursion (``finish_i = c_i + max(f₀, max_{j≤i}(a_j −
  c_{j−1}))`` with ``c`` the in-segment service cumsum) priced with three
  ``cumsum``/``maximum.accumulate`` passes over the frames of all nodes at
  once.  Exact for work-conserving service (policy ``none``) under any
  static per-window order — FIFO or EDF.
* :func:`policy_advance_kernel` — the reneging disciplines (``drop`` /
  ``degrade`` / ``reject``) have a data-dependent recursion (whether frame
  *i* consumes service depends on every earlier decision), so they run as an
  exact sequential sweep over the same sorted arrays; the no-policy
  vectorized kernel is its fixture in the tests.

:class:`NodeQueues` owns the persistent per-node state (``free_at_s`` — when
each node's server drains) and is advanced once per simulator tick with that
tick's emitted frames; ``backlog_s(now)`` is the expected wait a new arrival
would see, which queue-aware admission prices into the admission bar
(:class:`~repro.runtime.serve.AdmissionController`).

Deadline classes (§I timeliness, one ``deadline_s`` per class) ride along as
per-frame *absolute* deadlines: EDF orders by them, the overload policies
renege against them, and the metrics layer buckets misses by class.
"""

from __future__ import annotations

import dataclasses

import numpy as np

DISCIPLINES = ("fifo", "edf")
OVERLOAD_POLICIES = ("none", "drop", "degrade", "reject")


@dataclasses.dataclass(frozen=True)
class DeadlineClass:
    """One timeliness tier: frames of this class must complete within
    ``deadline_s`` of emission (the paper's surveillance deadline, split
    into tiers the way a mixed detection/tracking/alert workload needs)."""

    name: str
    deadline_s: float


DEFAULT_CLASSES: tuple[DeadlineClass, ...] = (
    DeadlineClass("interactive", 0.8),
    DeadlineClass("standard", 1.5),
    DeadlineClass("batch", 6.0),
)


@dataclasses.dataclass(frozen=True)
class ServicePolicy:
    """How a node's queue behaves, especially past saturation.

    ``discipline`` orders each advance window (``fifo``: arrival order;
    ``edf``: ascending absolute deadline).  ``overload`` is what happens to
    frames the server cannot meet:

    * ``none``   — serve everything; waits grow without bound (the baseline
      whose p99 the drop/degrade policies are measured against);
    * ``drop``   — a frame whose service would *start* past its deadline is
      dropped from the head without consuming service (drop-oldest);
    * ``degrade``— a frame whose full service would *finish* past its
      deadline is served in degraded form at ``degrade_factor`` × the
      service demand (skip-to-keep-up: run the light tracker, not the
      detector);
    * ``reject`` — a frame whose projected finish is already past its
      deadline on *arrival* never enters the queue (admission at the node).
    """

    discipline: str = "fifo"
    overload: str = "none"
    degrade_factor: float = 0.25

    def __post_init__(self):
        if self.discipline not in DISCIPLINES:
            raise ValueError(f"unknown queue discipline "
                             f"{self.discipline!r}; one of {DISCIPLINES}")
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {self.overload!r}; "
                             f"one of {OVERLOAD_POLICIES}")
        if not (0.0 <= self.degrade_factor <= 1.0):
            raise ValueError(f"degrade_factor must be in [0, 1], "
                             f"got {self.degrade_factor}")

    @classmethod
    def parse(cls, spec: str) -> "ServicePolicy":
        """``"fifo"`` / ``"edf"`` / ``"fifo+drop"`` / ``"edf+degrade:0.5"``
        → a policy (discipline, then an optional overload clause)."""
        head, _, tail = spec.partition("+")
        kw: dict = {"discipline": head}
        if tail:
            overload, _, val = tail.partition(":")
            kw["overload"] = overload
            if val:
                if overload != "degrade":
                    raise ValueError(
                        f"only 'degrade' takes a parameter, got {spec!r}")
                kw["degrade_factor"] = float(val)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class QueueOutcome:
    """Per-frame result of one queue advance (arrays aligned with the
    *caller's* frame order, not the internal sorted order)."""

    start_s: np.ndarray        # service start (emission-relative absolute s)
    finish_s: np.ndarray       # service completion (inf where not completed)
    wait_s: np.ndarray         # start − arrival for completed frames, else inf
    service_used_s: np.ndarray  # 0 where dropped/rejected; degraded × factor
    completed: np.ndarray      # bool — produced a decision
    dropped: np.ndarray        # bool — reneged at the head past deadline
    rejected: np.ndarray       # bool — turned away on arrival
    degraded: np.ndarray       # bool — served the skip/light variant


def _segment_starts(node_sorted: np.ndarray) -> np.ndarray:
    """Bool mask marking the first frame of each node's run (sorted input)."""
    starts = np.ones(node_sorted.shape[0], bool)
    starts[1:] = node_sorted[1:] != node_sorted[:-1]
    return starts


def _segmented_cumsum(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Inclusive cumsum of ``x`` restarting at every segment start."""
    cs = np.cumsum(x)
    base = np.where(starts, cs - x, 0.0)
    np.maximum.accumulate(base, out=base)
    return cs - base


def _segmented_cummax(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Running max of ``x`` restarting at every segment start (offset
    trick: segment ids are non-decreasing, so shifting each segment by
    ``seg_id × span`` makes a global cummax respect the boundaries)."""
    seg_id = np.cumsum(starts) - 1
    finite = x[np.isfinite(x)]
    span = (float(finite.max() - finite.min()) + 1.0) if finite.size else 1.0
    shifted = x + seg_id * span
    return np.maximum.accumulate(shifted) - seg_id * span


def fifo_advance_kernel(node: np.ndarray, arrival_s: np.ndarray,
                        service_s: np.ndarray,
                        free_at_s: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """The vectorized queue-advance kernel (work-conserving, no reneging).

    Frames must be sorted by ``(node, serve order)``; ``free_at_s`` is each
    node's current server-busy-until time.  Returns ``(start_s, finish_s)``
    in the given order via the segmented Lindley recursion — O(n) numpy,
    ~10⁷ frames/s, which is what makes 10⁵–10⁶-frame scenarios feasible.
    """
    if node.size == 0:
        return np.zeros(0), np.zeros(0)
    starts = _segment_starts(node)
    c = _segmented_cumsum(service_s, starts)          # in-segment cumsum
    c_excl = c - service_s
    head = arrival_s - c_excl
    # The node's pre-existing backlog is a virtual zeroth frame finishing at
    # free_at_s[node]; it enters the max with an exclusive cumsum of 0.
    head = np.where(starts, np.maximum(head, free_at_s[node]), head)
    finish = c + _segmented_cummax(head, starts)
    return finish - service_s, finish


def policy_advance_kernel(node: np.ndarray, arrival_s: np.ndarray,
                          service_s: np.ndarray, deadline_abs_s: np.ndarray,
                          free_at_s: np.ndarray,
                          policy: ServicePolicy) -> QueueOutcome:
    """Exact sequential queue advance with the reneging policies.

    Same sorted-input contract as :func:`fifo_advance_kernel`.  The
    recursion is inherently data-dependent (a drop frees the very service
    time that decides the next frame's fate), so this sweeps the sorted
    arrays once in Python — O(n) with small constants; the vectorized
    kernel above takes over whenever ``policy.overload == "none"``.
    """
    n = node.shape[0]
    start = np.zeros(n)
    finish = np.full(n, np.inf)
    used = np.zeros(n)
    completed = np.zeros(n, bool)
    dropped = np.zeros(n, bool)
    rejected = np.zeros(n, bool)
    degraded = np.zeros(n, bool)
    free = free_at_s.copy()
    overload, factor = policy.overload, policy.degrade_factor
    nodes_l = node.tolist()
    arr_l = arrival_s.tolist()
    srv_l = service_s.tolist()
    ddl_l = deadline_abs_s.tolist()
    for i in range(n):
        nd = nodes_l[i]
        st = max(arr_l[i], free[nd])
        svc = srv_l[i]
        if overload == "reject" and st + svc > ddl_l[i]:
            rejected[i] = True
            continue
        if overload == "drop" and st > ddl_l[i]:
            dropped[i] = True
            start[i] = st           # when the head reached it (provenance)
            continue
        if overload == "degrade" and st + svc > ddl_l[i]:
            svc *= factor
            degraded[i] = True
        start[i] = st
        finish[i] = st + svc
        used[i] = svc
        completed[i] = True
        free[nd] = finish[i]
    wait = np.where(completed, start - arrival_s, np.inf)
    return QueueOutcome(start, finish, wait, used, completed, dropped,
                        rejected, degraded)


class NodeQueues:
    """Persistent per-node queue state, advanced one window at a time.

    One instance == one swarm run.  The simulator emits a window of frames
    per tick (struct-of-arrays) and calls :meth:`advance`; the queue carries
    ``free_at_s`` — each node's server-busy-until time — across windows, so
    backlog accumulates exactly under sustained overload.  Ordering inside a
    window follows the policy's discipline (FIFO: emission order; EDF:
    ascending absolute deadline); frames of *earlier* windows are already
    committed, which makes EDF a per-window (tick-granular) reordering —
    the honest discrete-time reading of "earliest deadline first".
    """

    def __init__(self, n_nodes: int, policy: ServicePolicy = ServicePolicy()):
        self.n_nodes = n_nodes
        self.policy = policy
        self.free_at_s = np.zeros(n_nodes)
        # offered load per node: total service seconds presented (including
        # frames a policy later drops/rejects) — max(demand_s)/horizon is
        # the realized overload factor at the hottest queue
        self.demand_s = np.zeros(n_nodes)
        self.n_enqueued = 0
        self.n_completed = 0
        self.n_dropped = 0
        self.n_rejected = 0
        self.n_degraded = 0

    def backlog_s(self, now_s: float) -> np.ndarray:
        """(N,) expected wait of a frame arriving at each node *now* — the
        queue-depth term admission prices into its bar."""
        return np.maximum(self.free_at_s - now_s, 0.0)

    def snapshot(self) -> dict:
        """Lifetime queue tallies for the metrics registry (``queue.*`` in
        ``MetricsRegistry.snapshot()``): counters plus the realized offered
        load at the hottest node."""
        return {"queue.enqueued": self.n_enqueued,
                "queue.completed": self.n_completed,
                "queue.dropped": self.n_dropped,
                "queue.rejected": self.n_rejected,
                "queue.degraded": self.n_degraded,
                "queue.max_demand_s": float(self.demand_s.max())
                if self.demand_s.size else 0.0}

    def advance(self, node: np.ndarray, arrival_s: np.ndarray,
                service_s: np.ndarray,
                deadline_abs_s: np.ndarray) -> QueueOutcome:
        """Advance all queues through one window of emitted frames.

        Inputs are parallel arrays in emission order; the outcome is
        returned in that same order.  Updates ``free_at_s`` and counters.
        """
        n = int(node.shape[0])
        if n == 0:
            empty = np.zeros(0)
            eb = np.zeros(0, bool)
            return QueueOutcome(empty, empty, empty, empty, eb, eb, eb, eb)
        node = np.asarray(node, np.int64)
        arrival_s = np.asarray(arrival_s, float)
        service_s = np.asarray(service_s, float)
        deadline_abs_s = np.asarray(deadline_abs_s, float)
        if self.policy.discipline == "edf":
            order = np.lexsort((deadline_abs_s, node))
        else:
            order = np.lexsort((np.arange(n), node))
        inv = np.empty(n, np.int64)
        inv[order] = np.arange(n)

        ns, as_, ss, ds = (node[order], arrival_s[order], service_s[order],
                           deadline_abs_s[order])
        if self.policy.overload == "none":
            start, finish = fifo_advance_kernel(ns, as_, ss, self.free_at_s)
            completed = np.ones(n, bool)
            eb = np.zeros(n, bool)
            out = QueueOutcome(start, finish, start - as_, ss.copy(),
                               completed, eb, eb.copy(), eb.copy())
        else:
            out = policy_advance_kernel(ns, as_, ss, ds, self.free_at_s,
                                        self.policy)
        # Commit per-node server state: the last completed frame per segment.
        last = np.zeros(self.n_nodes)
        np.maximum.at(last, ns[out.completed], out.finish_s[out.completed])
        self.free_at_s = np.maximum(self.free_at_s, last)

        self.demand_s += np.bincount(ns, weights=ss,
                                     minlength=self.n_nodes)
        self.n_enqueued += n
        self.n_completed += int(out.completed.sum())
        self.n_dropped += int(out.dropped.sum())
        self.n_rejected += int(out.rejected.sum())
        self.n_degraded += int(out.degraded.sum())
        return QueueOutcome(out.start_s[inv], out.finish_s[inv],
                            out.wait_s[inv], out.service_used_s[inv],
                            out.completed[inv], out.dropped[inv],
                            out.rejected[inv], out.degraded[inv])


def tail_percentiles(latencies: np.ndarray) -> dict[str, float]:
    """p50/p99/p999 of a latency sample (inf-guarded, empty ⇒ inf) — the
    tail metrics the ROADMAP's production-traffic goal is judged on."""
    finite = latencies[np.isfinite(latencies)]
    if finite.size == 0:
        return {"p50_s": float("inf"), "p99_s": float("inf"),
                "p999_s": float("inf")}
    p50, p99, p999 = np.percentile(finite, [50.0, 99.0, 99.9])
    return {"p50_s": float(p50), "p99_s": float(p99), "p999_s": float(p999)}
