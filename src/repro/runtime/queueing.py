"""Per-node request queues: the always-on serving substrate.

The simulator used to serve every frame *within* its tick — a node could
absorb unlimited work per time step, so overload, head-of-line blocking and
tail latency were unobservable.  This module is the layer where a saturated
node exists: each frame occupies its placed node's queue for its
measured/modeled stage wall, waits behind earlier frames, and under overload
the :class:`ServicePolicy` decides what to drop, degrade, or turn away (the
``fast_mot`` skip/degrade discipline: a real-time tracker that falls behind
skips the expensive detector rather than queueing into uselessness).

Everything is struct-of-arrays over frames — numpy arrays for node id,
arrival time, service demand and absolute deadline — so scenarios with
10⁵–10⁶ frames advance through a handful of vectorized kernels instead of a
Python event loop:

* :func:`fifo_advance_kernel` — the vectorized queue-advance kernel: one
  segmented Lindley recursion (``finish_i = c_i + max(f₀, max_{j≤i}(a_j −
  c_{j−1}))`` with ``c`` the in-segment service cumsum) priced with three
  ``cumsum``/``maximum.accumulate`` passes over the frames of all nodes at
  once.  Exact for work-conserving service (policy ``none``) under any
  static per-window order — FIFO or EDF.
* :func:`policy_advance_kernel` — the reneging disciplines (``drop`` /
  ``degrade`` / ``reject``) have a data-dependent recursion (whether frame
  *i* consumes service depends on every earlier decision), so they run as an
  exact sequential sweep over the same sorted arrays; the no-policy
  vectorized kernel is its fixture in the tests.

:class:`NodeQueues` owns the persistent per-node state (``free_at_s`` — when
each node's server drains) and is advanced once per simulator tick with that
tick's emitted frames; ``backlog_s(now)`` is the expected wait a new arrival
would see, which queue-aware admission prices into the admission bar
(:class:`~repro.runtime.serve.AdmissionController`).

Deadline classes (§I timeliness, one ``deadline_s`` per class) ride along as
per-frame *absolute* deadlines: EDF orders by them, the overload policies
renege against them, and the metrics layer buckets misses by class.
"""

from __future__ import annotations

import dataclasses

import numpy as np

DISCIPLINES = ("fifo", "edf")
OVERLOAD_POLICIES = ("none", "drop", "degrade", "reject")


@dataclasses.dataclass(frozen=True)
class DeadlineClass:
    """One timeliness tier: frames of this class must complete within
    ``deadline_s`` of emission (the paper's surveillance deadline, split
    into tiers the way a mixed detection/tracking/alert workload needs)."""

    name: str
    deadline_s: float


DEFAULT_CLASSES: tuple[DeadlineClass, ...] = (
    DeadlineClass("interactive", 0.8),
    DeadlineClass("standard", 1.5),
    DeadlineClass("batch", 6.0),
)


@dataclasses.dataclass(frozen=True)
class ServicePolicy:
    """How a node's queue behaves, especially past saturation.

    ``discipline`` orders each advance window (``fifo``: arrival order;
    ``edf``: ascending absolute deadline).  ``overload`` is what happens to
    frames the server cannot meet:

    * ``none``   — serve everything; waits grow without bound (the baseline
      whose p99 the drop/degrade policies are measured against);
    * ``drop``   — a frame whose service would *start* past its deadline is
      dropped from the head without consuming service (drop-oldest);
    * ``degrade``— a frame whose full service would *finish* past its
      deadline is served in degraded form at ``degrade_factor`` × the
      service demand (skip-to-keep-up: run the light tracker, not the
      detector);
    * ``reject`` — a frame whose projected finish is already past its
      deadline on *arrival* never enters the queue (admission at the node).
    """

    discipline: str = "fifo"
    overload: str = "none"
    degrade_factor: float = 0.25

    def __post_init__(self):
        if self.discipline not in DISCIPLINES:
            raise ValueError(f"unknown queue discipline "
                             f"{self.discipline!r}; one of {DISCIPLINES}")
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {self.overload!r}; "
                             f"one of {OVERLOAD_POLICIES}")
        if not (0.0 <= self.degrade_factor <= 1.0):
            raise ValueError(f"degrade_factor must be in [0, 1], "
                             f"got {self.degrade_factor}")

    @classmethod
    def parse(cls, spec: str) -> "ServicePolicy":
        """``"fifo"`` / ``"edf"`` / ``"fifo+drop"`` / ``"edf+degrade:0.5"``
        → a policy (discipline, then an optional overload clause)."""
        head, _, tail = spec.partition("+")
        kw: dict = {"discipline": head}
        if tail:
            overload, _, val = tail.partition(":")
            kw["overload"] = overload
            if val:
                if overload != "degrade":
                    raise ValueError(
                        f"only 'degrade' takes a parameter, got {spec!r}")
                kw["degrade_factor"] = float(val)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class QueueOutcome:
    """Per-frame result of one queue advance (arrays aligned with the
    *caller's* frame order, not the internal sorted order)."""

    start_s: np.ndarray        # service start (emission-relative absolute s)
    finish_s: np.ndarray       # service completion (inf where not completed)
    wait_s: np.ndarray         # start − arrival for completed frames, else inf
    service_used_s: np.ndarray  # 0 where dropped/rejected; degraded × factor
    completed: np.ndarray      # bool — produced a decision
    dropped: np.ndarray        # bool — reneged at the head past deadline
    rejected: np.ndarray       # bool — turned away on arrival
    degraded: np.ndarray       # bool — served the skip/light variant


def _segment_starts(node_sorted: np.ndarray) -> np.ndarray:
    """Bool mask marking the first frame of each node's run (sorted input)."""
    starts = np.ones(node_sorted.shape[0], bool)
    starts[1:] = node_sorted[1:] != node_sorted[:-1]
    return starts


def _segmented_cumsum(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Inclusive cumsum of ``x`` restarting at every segment start."""
    cs = np.cumsum(x)
    base = np.where(starts, cs - x, 0.0)
    np.maximum.accumulate(base, out=base)
    return cs - base


def _segmented_cummax(x: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Running max of ``x`` restarting at every segment start (offset
    trick: segment ids are non-decreasing, so shifting each segment by
    ``seg_id × span`` makes a global cummax respect the boundaries)."""
    seg_id = np.cumsum(starts) - 1
    finite = x[np.isfinite(x)]
    span = (float(finite.max() - finite.min()) + 1.0) if finite.size else 1.0
    shifted = x + seg_id * span
    return np.maximum.accumulate(shifted) - seg_id * span


def fifo_advance_kernel(node: np.ndarray, arrival_s: np.ndarray,
                        service_s: np.ndarray,
                        free_at_s: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """The vectorized queue-advance kernel (work-conserving, no reneging).

    Frames must be sorted by ``(node, serve order)``; ``free_at_s`` is each
    node's current server-busy-until time.  Returns ``(start_s, finish_s)``
    in the given order via the segmented Lindley recursion — O(n) numpy,
    ~10⁷ frames/s, which is what makes 10⁵–10⁶-frame scenarios feasible.
    """
    if node.size == 0:
        return np.zeros(0), np.zeros(0)
    starts = _segment_starts(node)
    c = _segmented_cumsum(service_s, starts)          # in-segment cumsum
    c_excl = c - service_s
    head = arrival_s - c_excl
    # The node's pre-existing backlog is a virtual zeroth frame finishing at
    # free_at_s[node]; it enters the max with an exclusive cumsum of 0.
    head = np.where(starts, np.maximum(head, free_at_s[node]), head)
    finish = c + _segmented_cummax(head, starts)
    return finish - service_s, finish


def policy_advance_kernel(node: np.ndarray, arrival_s: np.ndarray,
                          service_s: np.ndarray, deadline_abs_s: np.ndarray,
                          free_at_s: np.ndarray,
                          policy: ServicePolicy) -> QueueOutcome:
    """Exact sequential queue advance with the reneging policies.

    Same sorted-input contract as :func:`fifo_advance_kernel`.  The
    recursion is inherently data-dependent (a drop frees the very service
    time that decides the next frame's fate), so this sweeps the sorted
    arrays once in Python — O(n) with small constants; the vectorized
    kernel above takes over whenever ``policy.overload == "none"``.
    """
    n = node.shape[0]
    start = np.zeros(n)
    finish = np.full(n, np.inf)
    used = np.zeros(n)
    completed = np.zeros(n, bool)
    dropped = np.zeros(n, bool)
    rejected = np.zeros(n, bool)
    degraded = np.zeros(n, bool)
    free = free_at_s.copy()
    overload, factor = policy.overload, policy.degrade_factor
    nodes_l = node.tolist()
    arr_l = arrival_s.tolist()
    srv_l = service_s.tolist()
    ddl_l = deadline_abs_s.tolist()
    for i in range(n):
        nd = nodes_l[i]
        st = max(arr_l[i], free[nd])
        svc = srv_l[i]
        if overload == "reject" and st + svc > ddl_l[i]:
            rejected[i] = True
            continue
        if overload == "drop" and st > ddl_l[i]:
            dropped[i] = True
            start[i] = st           # when the head reached it (provenance)
            continue
        if overload == "degrade" and st + svc > ddl_l[i]:
            svc *= factor
            degraded[i] = True
        start[i] = st
        finish[i] = st + svc
        used[i] = svc
        completed[i] = True
        free[nd] = finish[i]
    wait = np.where(completed, start - arrival_s, np.inf)
    return QueueOutcome(start, finish, wait, used, completed, dropped,
                        rejected, degraded)


class NodeQueues:
    """Persistent per-node queue state, advanced one window at a time.

    One instance == one swarm run.  The simulator emits a window of frames
    per tick (struct-of-arrays) and calls :meth:`advance`; the queue carries
    ``free_at_s`` — each node's server-busy-until time — across windows, so
    backlog accumulates exactly under sustained overload.  Ordering inside a
    window follows the policy's discipline (FIFO: emission order; EDF:
    ascending absolute deadline); frames of *earlier* windows are already
    committed, which makes EDF a per-window (tick-granular) reordering —
    the honest discrete-time reading of "earliest deadline first".
    """

    def __init__(self, n_nodes: int, policy: ServicePolicy = ServicePolicy()):
        self.n_nodes = n_nodes
        self.policy = policy
        self.free_at_s = np.zeros(n_nodes)
        # offered load per node: total service seconds presented (including
        # frames a policy later drops/rejects) — max(demand_s)/horizon is
        # the realized overload factor at the hottest queue
        self.demand_s = np.zeros(n_nodes)
        self.n_enqueued = 0
        self.n_completed = 0
        self.n_dropped = 0
        self.n_rejected = 0
        self.n_degraded = 0

    def backlog_s(self, now_s: float) -> np.ndarray:
        """(N,) expected wait of a frame arriving at each node *now* — the
        queue-depth term admission prices into its bar."""
        return np.maximum(self.free_at_s - now_s, 0.0)

    def snapshot(self) -> dict:
        """Lifetime queue tallies for the metrics registry (``queue.*`` in
        ``MetricsRegistry.snapshot()``): counters plus the realized offered
        load at the hottest node."""
        return {"queue.enqueued": self.n_enqueued,
                "queue.completed": self.n_completed,
                "queue.dropped": self.n_dropped,
                "queue.rejected": self.n_rejected,
                "queue.degraded": self.n_degraded,
                "queue.max_demand_s": float(self.demand_s.max())
                if self.demand_s.size else 0.0}

    def advance(self, node: np.ndarray, arrival_s: np.ndarray,
                service_s: np.ndarray,
                deadline_abs_s: np.ndarray) -> QueueOutcome:
        """Advance all queues through one window of emitted frames.

        Inputs are parallel arrays in emission order; the outcome is
        returned in that same order.  Updates ``free_at_s`` and counters.
        """
        n = int(node.shape[0])
        if n == 0:
            empty = np.zeros(0)
            eb = np.zeros(0, bool)
            return QueueOutcome(empty, empty, empty, empty, eb, eb, eb, eb)
        node = np.asarray(node, np.int64)
        arrival_s = np.asarray(arrival_s, float)
        service_s = np.asarray(service_s, float)
        deadline_abs_s = np.asarray(deadline_abs_s, float)
        if self.policy.discipline == "edf":
            order = np.lexsort((deadline_abs_s, node))
        else:
            order = np.lexsort((np.arange(n), node))
        inv = np.empty(n, np.int64)
        inv[order] = np.arange(n)

        ns, as_, ss, ds = (node[order], arrival_s[order], service_s[order],
                           deadline_abs_s[order])
        if self.policy.overload == "none":
            start, finish = fifo_advance_kernel(ns, as_, ss, self.free_at_s)
            completed = np.ones(n, bool)
            eb = np.zeros(n, bool)
            out = QueueOutcome(start, finish, start - as_, ss.copy(),
                               completed, eb, eb.copy(), eb.copy())
        else:
            out = policy_advance_kernel(ns, as_, ss, ds, self.free_at_s,
                                        self.policy)
        # Commit per-node server state: the last completed frame per segment.
        last = np.zeros(self.n_nodes)
        np.maximum.at(last, ns[out.completed], out.finish_s[out.completed])
        self.free_at_s = np.maximum(self.free_at_s, last)

        self.demand_s += np.bincount(ns, weights=ss,
                                     minlength=self.n_nodes)
        self.n_enqueued += n
        self.n_completed += int(out.completed.sum())
        self.n_dropped += int(out.dropped.sum())
        self.n_rejected += int(out.rejected.sum())
        self.n_degraded += int(out.degraded.sum())
        return QueueOutcome(out.start_s[inv], out.finish_s[inv],
                            out.wait_s[inv], out.service_used_s[inv],
                            out.completed[inv], out.dropped[inv],
                            out.rejected[inv], out.degraded[inv])


def n_path_resources(n_nodes: int) -> int:
    """Size of the combined resource space the tandem network queues over:
    one compute server per node plus one server per *directed* link."""
    return n_nodes + n_nodes * n_nodes


def link_resource(n_nodes: int, a, b):
    """Resource id of the directed link ``a → b`` (vectorized over arrays).

    Compute node ``i`` keeps id ``i``; links occupy ``N + a·N + b`` so every
    hop of a placed path — stage walls *and* transfers — is a first-class
    server with its own FIFO/EDF queue.
    """
    return n_nodes + a * n_nodes + b


@dataclasses.dataclass(frozen=True)
class PathOutcome:
    """Per-frame, per-hop result of one tandem advance (caller's frame
    order; hop axis padded — ``res < 0`` hops carry ``wait = service = 0``).
    """

    start_s: np.ndarray         # (F, H) hop service start
    finish_s: np.ndarray        # (F, H) hop service completion
    wait_s: np.ndarray          # (F, H) start − previous hop's finish
    service_used_s: np.ndarray  # (F, H) 0 where padded/dropped; degraded ×f
    done_s: np.ndarray          # (F,) last real hop's finish (inf if not)
    lat_s: np.ndarray           # (F,) Σ_h (wait_h + service_h), hop order
    wait_total_s: np.ndarray    # (F,) Σ_h wait_h
    completed: np.ndarray       # (F,) bool
    dropped: np.ndarray         # (F,) bool — reneged at some hop's head
    rejected: np.ndarray        # (F,) bool — turned away at the first hop
    degraded: np.ndarray        # (F,) bool — any hop served the light form


def path_advance_kernel(res: np.ndarray, service_s: np.ndarray,
                        arrival_s: np.ndarray, free_at_s: np.ndarray,
                        priority: np.ndarray | None = None,
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Generalized segmented-Lindley advance over a tandem of hops.

    ``res`` is ``(F, H)`` resource ids per frame and hop (compute nodes and
    directed links share one id space, ``-1`` pads shorter paths) and
    ``service_s`` the matching hop services.  A frame's arrival at hop
    ``h`` is its *finish at hop h−1* (hop 0 arrives at ``arrival_s``), so
    the whole cascade advances hop-major: for each hop level, the frames
    holding a real hop are sorted by ``(resource, readiness)`` and pushed
    through :func:`fifo_advance_kernel` against the running ``free_at_s``
    of the combined resource space — H sweeps of the same O(F) vectorized
    recursion instead of a per-frame event loop.

    ``priority`` (optional, per frame) replaces readiness as the in-wave
    serve order within a resource (EDF passes absolute deadlines).
    Returns ``(start_s, finish_s, free_out)`` with the per-hop schedule in
    the caller's frame order and the committed busy-until times;
    ``free_at_s`` itself is not mutated.
    """
    res = np.asarray(res, np.int64)
    service_s = np.asarray(service_s, float)
    n_frames, n_hops = res.shape
    start = np.zeros((n_frames, n_hops))
    finish = np.zeros((n_frames, n_hops))
    ready = np.asarray(arrival_s, float).copy()
    free = np.asarray(free_at_s, float).copy()
    for h in range(n_hops):
        r = res[:, h]
        valid = r >= 0
        start[:, h] = ready
        finish[:, h] = ready
        if not valid.any():
            continue
        idx = np.flatnonzero(valid)
        key = ready[idx] if priority is None else priority[idx]
        order = idx[np.lexsort((idx, key, r[idx]))]
        rs = r[order]
        st, fin = fifo_advance_kernel(rs, ready[order],
                                      service_s[order, h], free)
        start[order, h] = st
        finish[order, h] = fin
        np.maximum.at(free, rs, fin)
        ready[order] = fin
    return start, finish, free


def path_sweep_reference(res: np.ndarray, service_s: np.ndarray,
                         arrival_s: np.ndarray, free_at_s: np.ndarray,
                         priority: np.ndarray | None = None,
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Scalar python sweep with the identical hop-major FCFS discipline —
    the exactness fixture (and the denominator of the S8 speedup lock)."""
    res = np.asarray(res, np.int64)
    service_s = np.asarray(service_s, float)
    n_frames, n_hops = res.shape
    start = np.zeros((n_frames, n_hops))
    finish = np.zeros((n_frames, n_hops))
    ready = [float(a) for a in np.asarray(arrival_s, float)]
    free = [float(f) for f in np.asarray(free_at_s, float)]
    for h in range(n_hops):
        wave = [i for i in range(n_frames) if res[i, h] >= 0]
        if priority is None:
            wave.sort(key=lambda i: (res[i, h], ready[i], i))
        else:
            wave.sort(key=lambda i: (res[i, h], priority[i], i))
        for i in range(n_frames):
            start[i, h] = finish[i, h] = ready[i]
        for i in wave:
            rid = int(res[i, h])
            st = max(ready[i], free[rid])
            fin = st + float(service_s[i, h])
            start[i, h] = st
            finish[i, h] = fin
            free[rid] = fin
            ready[i] = fin
    return start, finish, np.asarray(free)


def path_policy_sweep(res: np.ndarray, service_s: np.ndarray,
                      arrival_s: np.ndarray, deadline_abs_s: np.ndarray,
                      free_at_s: np.ndarray, policy: ServicePolicy,
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray, dict]:
    """Hop-major tandem advance with the reneging overload policies.

    Same hop-major wave order as :func:`path_advance_kernel` (EDF swaps the
    in-wave key for the absolute deadline), but sequential within each wave
    because reneging is data-dependent:

    * ``reject`` — decided once at the frame's *first* real hop: if its
      start there plus the sum of all remaining hop services (a no-wait
      lower bound on completion) already overruns the deadline, the frame
      never consumes any hop;
    * ``drop``   — at any hop whose service would *start* past the
      deadline the frame reneges and abandons the rest of its cascade;
    * ``degrade``— any hop whose full service would finish late is served
      at ``degrade_factor`` × its demand (the light variant of that stage
      or transfer).
    """
    res = np.asarray(res, np.int64)
    service_s = np.asarray(service_s, float)
    n_frames, n_hops = res.shape
    start = np.zeros((n_frames, n_hops))
    finish = np.zeros((n_frames, n_hops))
    used = np.zeros((n_frames, n_hops))
    dropped = np.zeros(n_frames, bool)
    rejected = np.zeros(n_frames, bool)
    degraded = np.zeros(n_frames, bool)
    started = np.zeros(n_frames, bool)
    ready = [float(a) for a in np.asarray(arrival_s, float)]
    free = [float(f) for f in np.asarray(free_at_s, float)]
    remaining = np.cumsum(service_s[:, ::-1], axis=1)[:, ::-1]
    ddl = np.asarray(deadline_abs_s, float)
    edf = policy.discipline == "edf"
    overload, factor = policy.overload, policy.degrade_factor
    for h in range(n_hops):
        for i in range(n_frames):
            start[i, h] = finish[i, h] = ready[i]
        wave = [i for i in range(n_frames)
                if res[i, h] >= 0 and not dropped[i] and not rejected[i]]
        if edf:
            wave.sort(key=lambda i: (res[i, h], ddl[i], i))
        else:
            wave.sort(key=lambda i: (res[i, h], ready[i], i))
        for i in wave:
            rid = int(res[i, h])
            st = max(ready[i], free[rid])
            svc = float(service_s[i, h])
            if overload == "reject" and not started[i]:
                if st + float(remaining[i, h]) > ddl[i]:
                    rejected[i] = True
                    continue
            if overload == "drop" and st > ddl[i]:
                dropped[i] = True
                start[i, h] = st        # when the head reached it
                finish[i, h] = ready[i]
                continue
            if overload == "degrade" and st + svc > ddl[i]:
                svc *= factor
                degraded[i] = True
            started[i] = True
            start[i, h] = st
            finish[i, h] = st + svc
            used[i, h] = svc
            free[rid] = st + svc
            ready[i] = st + svc
    flags = {"dropped": dropped, "rejected": rejected, "degraded": degraded,
             "served_any": started}
    return start, finish, used, {"free": np.asarray(free), **flags}


class PathQueues:
    """Persistent tandem-network state: one server per node *and* per
    directed link, advanced one window of hop schedules at a time.

    The per-hop counterpart of :class:`NodeQueues` (DESIGN.md §10): a
    frame occupies, in order, its source uplink, each placed stage's
    compute server, and each stage boundary's link server — waiting behind
    cross-traffic at every hop, which is exactly the shared-relay
    contention the bottleneck model cannot see.  ``backlog_s`` spans the
    whole resource space so queue-aware admission can price the *summed*
    backlog along a candidate path.
    """

    def __init__(self, n_nodes: int, policy: ServicePolicy = ServicePolicy()):
        self.n_nodes = n_nodes
        self.policy = policy
        self.free_at_s = np.zeros(n_path_resources(n_nodes))
        self.demand_s = np.zeros(n_nodes)          # compute offered load
        self.link_demand_s = np.zeros(n_nodes * n_nodes)
        self.n_enqueued = 0
        self.n_completed = 0
        self.n_dropped = 0
        self.n_rejected = 0
        self.n_degraded = 0

    def backlog_s(self, now_s: float) -> np.ndarray:
        """(N + N²,) expected wait at each compute/link server *now*."""
        return np.maximum(self.free_at_s - now_s, 0.0)

    def snapshot(self) -> dict:
        return {"queue.enqueued": self.n_enqueued,
                "queue.completed": self.n_completed,
                "queue.dropped": self.n_dropped,
                "queue.rejected": self.n_rejected,
                "queue.degraded": self.n_degraded,
                "queue.max_demand_s": float(self.demand_s.max())
                if self.demand_s.size else 0.0,
                "queue.max_link_demand_s": float(self.link_demand_s.max())
                if self.link_demand_s.size else 0.0}

    def advance(self, res: np.ndarray, service_s: np.ndarray,
                arrival_s: np.ndarray,
                deadline_abs_s: np.ndarray) -> PathOutcome:
        """Advance the tandem network through one window of hop schedules.

        ``res``/``service_s`` are ``(F, H)`` in emission order (rows are
        frames, columns hops, ``-1`` pads).  Latency is accumulated in hop
        order (``lat ← lat + wait_h + service_h``) so an uncontended
        single-hop path reproduces the bottleneck model's
        ``base + wait + service`` float-for-float.
        """
        res = np.asarray(res, np.int64)
        n_frames = int(res.shape[0])
        if n_frames == 0:
            e2 = np.zeros((0, res.shape[1] if res.ndim == 2 else 0))
            e1 = np.zeros(0)
            eb = np.zeros(0, bool)
            return PathOutcome(e2, e2.copy(), e2.copy(), e2.copy(), e1,
                               e1.copy(), e1.copy(), eb, eb.copy(),
                               eb.copy(), eb.copy())
        service_s = np.asarray(service_s, float)
        arrival_s = np.asarray(arrival_s, float)
        deadline_abs_s = np.asarray(deadline_abs_s, float)
        prio = deadline_abs_s if self.policy.discipline == "edf" else None
        if self.policy.overload == "none":
            start, finish, free = path_advance_kernel(
                res, service_s, arrival_s, self.free_at_s, prio)
            used = np.where(res >= 0, service_s, 0.0)
            completed = np.ones(n_frames, bool)
            eb = np.zeros(n_frames, bool)
            dropped, rejected, degraded = eb, eb.copy(), eb.copy()
        else:
            start, finish, used, info = path_policy_sweep(
                res, service_s, arrival_s, deadline_abs_s, self.free_at_s,
                self.policy)
            free = info["free"]
            dropped, rejected = info["dropped"], info["rejected"]
            degraded = info["degraded"]
            completed = ~dropped & ~rejected
        self.free_at_s = np.maximum(self.free_at_s, free)

        prev = np.concatenate([arrival_s[:, None], finish[:, :-1]], axis=1)
        # No clipping at 0: the segmented cummax can land a start an ulp
        # below its arrival, and the bottleneck model keeps that sign —
        # preserving it is what makes single-hop tapes bit-identical.
        wait = np.where(res >= 0, start - prev, 0.0)
        lat = np.zeros(n_frames)
        wait_total = np.zeros(n_frames)
        for h in range(res.shape[1]):
            lat = lat + wait[:, h] + used[:, h]
            wait_total = wait_total + wait[:, h]
        last_real = np.where((res >= 0).any(axis=1),
                             res.shape[1] - 1 -
                             np.argmax((res >= 0)[:, ::-1], axis=1), 0)
        done = finish[np.arange(n_frames), last_real]
        done = np.where(completed, done, np.inf)
        lat = np.where(completed, lat, np.inf)

        node_hops = (res >= 0) & (res < self.n_nodes)
        link_hops = res >= self.n_nodes
        self.demand_s += np.bincount(
            res[node_hops], weights=service_s[node_hops],
            minlength=self.n_nodes)
        if link_hops.any():
            self.link_demand_s += np.bincount(
                res[link_hops] - self.n_nodes,
                weights=service_s[link_hops],
                minlength=self.n_nodes * self.n_nodes)
        self.n_enqueued += n_frames
        self.n_completed += int(completed.sum())
        self.n_dropped += int(dropped.sum())
        self.n_rejected += int(rejected.sum())
        self.n_degraded += int(degraded.sum())
        return PathOutcome(start, finish, wait, used, done, lat, wait_total,
                           completed, dropped, rejected, degraded)


def tail_percentiles(latencies: np.ndarray) -> dict[str, float]:
    """p50/p99/p999 of a latency sample (inf-guarded, empty ⇒ inf) — the
    tail metrics the ROADMAP's production-traffic goal is judged on."""
    finite = latencies[np.isfinite(latencies)]
    if finite.size == 0:
        return {"p50_s": float("inf"), "p99_s": float("inf"),
                "p999_s": float("inf")}
    p50, p99, p999 = np.percentile(finite, [50.0, 99.0, 99.9])
    return {"p50_s": float(p50), "p99_s": float(p99), "p999_s": float(p999)}
