"""Serving runtime: batched prefill + decode with OULD request scheduling.

The paper's scenario is R concurrent classification requests placed across
constrained nodes.  The serving loop mirrors it: incoming requests are
admitted/placed by OULD over the node pool (devices or UAVs), then executed
as batched prefill + decode steps with donated caches.  On CPU/tests this
runs the real model; the scheduling layer is topology-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import Problem, ResolveStats
from ..core.latency import evaluate
from ..core.planner import Plan, Planner, TopologyView, get_planner, make_view
from ..core.profiles import lm_profile
from ..obs import ADMISSION, NULL_TRACER, SOLVER
from . import steps as steps_mod


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 128
    batch_size: int = 4


class Server:
    """Minimal production-shaped server: admit → prefill → decode loop."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self._prefill = jax.jit(steps_mod.make_prefill_step(
            cfg, max_len=scfg.max_len))
        self._decode = jax.jit(steps_mod.make_decode_step(cfg),
                               donate_argnums=(2,))

    def generate(self, tokens: np.ndarray, steps: int) -> np.ndarray:
        """tokens: (B, S) prompt → (B, steps) generated ids (greedy)."""
        B, S = tokens.shape
        assert S + steps <= self.scfg.max_len
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)})
        out = []
        pos = jnp.int32(S)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok[:, 0]))
            logits, cache = self._decode(self.params, tok, cache, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            pos = pos + 1
        return np.stack(out, axis=1)


# ---------------------------------------------------------------------------
# OULD request admission/placement over a serving pool
# ---------------------------------------------------------------------------

class AdmissionController:
    """Epoch-based admission + placement for a serving pool.

    Strategy-agnostic: wraps any registered :class:`~repro.core.planner.
    Planner` (by name or instance) and feeds it one :class:`TopologyView`
    per admission round.  Stateful planners (``incremental``, warm
    ``ould-mp``) keep placements of persistent streams across rounds and
    cache constraint structure; stateless planners just get called.  One
    controller instance == one pool; per-round outages go through the
    view's ``alive`` mask.
    """

    def __init__(self, planner: Planner | str = "incremental",
                 tracer=None, queue_model: str = "bottleneck",
                 **planner_options):
        self.planner: Planner = (get_planner(planner, **planner_options)
                                 if isinstance(planner, str) else planner)
        # Which queueing substrate the backlog vector prices ("bottleneck":
        # (N,) per-node waits, gate at the heaviest stage's host; "perhop":
        # (N+N²,) per-server waits over compute nodes and directed links,
        # gate on the *summed* backlog along the whole candidate path).
        if queue_model not in ("bottleneck", "perhop"):
            raise ValueError(f"unknown queue_model {queue_model!r}")
        self.queue_model = queue_model
        # Observability (repro.obs): solver spans + admission verdicts are
        # emitted per round when a real Tracer is attached; the NullTracer
        # default keeps this path free.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # Per-round solve stats only — a Plan pins its bound Problem (rate
        # matrices), which must not accumulate over a long-running pool.
        self.history: list[ResolveStats] = []
        # Streams the queue-depth bar turned away last round (queue-aware
        # admission only; 0 otherwise).
        self.last_queue_rejected: int = 0

    def admit(self, problem: Problem, view: TopologyView | np.ndarray,
              request_ids=None, *, backlog_s: np.ndarray | None = None,
              deadline_s: np.ndarray | float | None = None,
              now_s: float | None = None) -> Plan:
        """Place this round's active request set; returns the :class:`Plan`.

        ``view`` may be a prepared TopologyView or a raw rate array (wrapped
        via :func:`make_view`); ``request_ids`` are stable stream ids for
        placement inheritance across rounds (ignored by stateless planners).

        When ``backlog_s`` (per-node expected queue wait, seconds) and
        ``deadline_s`` (per-request, broadcastable) are both given, admission
        prices queue depth into the bar: any planner-admitted request whose
        path latency *plus* the backlog at its bottleneck node would overrun
        its deadline is turned away (admitted→False, assign→-1) before the
        plan is returned.  Path-cost-only admission can place a stream onto
        a node whose queue already guarantees a deadline miss; this gate is
        what "expected wait = queue backlog" buys.  Note the gate runs after
        the solve, so warm planners still hold capacity for gated streams
        until the next round — conservative, never over-admits.

        ``now_s`` timestamps this round's trace events (simulated seconds in
        the swarm runtime); ``None`` falls back to the tracer's real-time
        clock (``tracer.now()``) — the CLI path.
        """
        if isinstance(view, np.ndarray):
            view = make_view(view)
        plan = self.planner.plan(problem, view, request_ids=request_ids)
        self.last_queue_rejected = 0
        if (backlog_s is not None and deadline_s is not None
                and plan.n_admitted):
            plan = self._queue_gate(plan, np.asarray(backlog_s, float),
                                    deadline_s)
        self.history.append(plan.solve_stats or ResolveStats(
            0, plan.solution.n_admitted, problem.n_nodes, True,
            plan.solve_time_s))
        if self.tracer.enabled:
            self._trace_round(plan, request_ids, now_s)
        return plan

    def _trace_round(self, plan: Plan, request_ids, now_s) -> None:
        """One SOLVER span per admission round (dur = the solve's wall
        seconds, rich args from ResolveStats incl. the cold-dispatch flag)
        plus per-request admit/reject instants on the ADMISSION track."""
        tr = self.tracer
        ts = float(now_s) if now_s is not None else tr.now()
        st = plan.solve_stats
        args: dict = {"n_admitted": int(plan.n_admitted),
                      "queue_gated": int(self.last_queue_rejected)}
        if st is not None:
            # cold_dispatch=True means solve_time_s paid for ≥1 XLA compile
            # — do not read this span's dur as steady-state solve cost.
            args.update(n_kept=int(st.n_kept), n_replaced=int(st.n_replaced),
                        cold=bool(st.cold), k=int(st.k),
                        n_batched=int(st.n_batched),
                        n_jit_compiles=int(st.n_jit_compiles),
                        cold_dispatch=bool(st.cold_dispatch))
        tr.intern("solve", "n_admitted", "queue_gated")
        tr.span(SOLVER, "solve", ts, float(plan.solve_time_s),
                a0=float(plan.n_admitted),
                a1=float(self.last_queue_rejected), args=args)
        if request_ids is None:
            return
        ids = np.asarray(request_ids, np.int64)
        adm = np.asarray(plan.admitted, bool)
        tss = np.full(ids.shape[0], ts)
        if adm.any():
            tr.instant_batch(ADMISSION, "admit", tss[adm], frame=ids[adm])
        if (~adm).any():
            tr.instant_batch(ADMISSION, "reject", tss[~adm],
                             frame=ids[~adm])

    def _queue_gate(self, plan: Plan, backlog_s: np.ndarray,
                    deadline_s: np.ndarray | float) -> Plan:
        """Reject planner-admitted requests whose expected queue wait (the
        backlog at their bottleneck node) pushes them past their deadline."""
        admitted = plan.admitted.copy()
        deadline = np.broadcast_to(np.asarray(deadline_s, float),
                                   admitted.shape)
        per_req = plan.evaluate().per_request_s
        comp = np.asarray(plan.problem.profile.compute_vector(), float)
        speed = plan.problem.compute_speed
        assign = plan.assign.copy()
        n_nodes = plan.problem.n_nodes
        sources = plan.problem.sources
        gated = 0
        for r in np.flatnonzero(admitted):
            path = assign[r]
            if self.queue_model == "perhop":
                # Sum the backlog over every server the candidate path
                # occupies: source uplink, each stage's compute node, and
                # each stage boundary's directed link (queueing.link_resource
                # id layout) — the tandem network's whole expected wait.
                src = int(sources[r])
                first = int(path[0])
                total = backlog_s[first] if first == src else (
                    backlog_s[n_nodes + src * n_nodes + first]
                    + backlog_s[first])
                for j in range(path.shape[0] - 1):
                    a, b = int(path[j]), int(path[j + 1])
                    if a != b:
                        total += (backlog_s[n_nodes + a * n_nodes + b]
                                  + backlog_s[b])
                if per_req[r] + total > deadline[r]:
                    admitted[r] = False
                    assign[r] = -1
                    gated += 1
                continue
            # bottleneck node = host of the largest stage wall on the path
            best_w, best_node, cur, w = -1.0, int(path[0]), int(path[0]), 0.0
            for j in range(path.shape[0]):
                node = int(path[j])
                if node != cur:
                    if w > best_w:
                        best_w, best_node = w, cur
                    cur, w = node, 0.0
                w += comp[j] / (speed[node] if speed is not None else 1.0)
            if w > best_w:
                best_w, best_node = w, cur
            if per_req[r] + backlog_s[best_node] > deadline[r]:
                admitted[r] = False
                assign[r] = -1
                gated += 1
        self.last_queue_rejected = gated
        if not gated:
            return plan
        sol = dataclasses.replace(plan.solution, assign=assign,
                                  admitted=admitted,
                                  status=plan.solution.status
                                  + f"+queue-gated:{gated}")
        sol = dataclasses.replace(
            sol, objective=evaluate(plan.problem, sol).comm_latency_s)
        return dataclasses.replace(plan, solution=sol)

    @property
    def total_solve_time_s(self) -> float:
        return float(sum(s.solve_time_s for s in self.history))


def schedule_requests(cfg: ModelConfig, *, n_nodes: int, requests: int,
                      hbm_bytes: float, flops_budget: float,
                      rates_bits: np.ndarray, seq: int = 2048,
                      planner: str = "ould-dp",
                      **planner_options: Any) -> tuple[Plan, Any]:
    """Place R concurrent serving requests' layer groups over the pool —
    the paper's multi-request placement applied to inference serving, via
    any registered planner (``planner_options`` configure it, e.g.
    ``sparse_k`` for the pruned-DP strategies).  Returns
    (Plan, Evaluation)."""
    profile = lm_profile(
        cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_ff=cfg.d_ff, vocab=cfg.vocab,
        seq=seq, moe_experts=cfg.moe.num_experts if cfg.moe else 0,
        moe_topk=cfg.moe.top_k if cfg.moe else 0, window=cfg.window)
    sources = np.arange(requests) % n_nodes
    prob = Problem(profile, np.full(n_nodes, hbm_bytes),
                   np.full(n_nodes, flops_budget), rates_bits,
                   sources.astype(np.int64),
                   compute_speed=np.full(n_nodes, 197e12))
    plan = get_planner(planner, **planner_options).plan(
        prob, make_view(rates_bits))
    return plan, plan.evaluate()
