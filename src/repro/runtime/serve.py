"""Serving runtime: batched prefill + decode with OULD request scheduling.

The paper's scenario is R concurrent classification requests placed across
constrained nodes.  The serving loop mirrors it: incoming requests are
admitted/placed by OULD over the node pool (devices or UAVs), then executed
as batched prefill + decode steps with donated caches.  On CPU/tests this
runs the real model; the scheduling layer is topology-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import Problem, ResolveStats
from ..core.planner import Plan, Planner, TopologyView, get_planner, make_view
from ..core.profiles import lm_profile
from . import steps as steps_mod


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 128
    batch_size: int = 4


class Server:
    """Minimal production-shaped server: admit → prefill → decode loop."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self._prefill = jax.jit(steps_mod.make_prefill_step(
            cfg, max_len=scfg.max_len))
        self._decode = jax.jit(steps_mod.make_decode_step(cfg),
                               donate_argnums=(2,))

    def generate(self, tokens: np.ndarray, steps: int) -> np.ndarray:
        """tokens: (B, S) prompt → (B, steps) generated ids (greedy)."""
        B, S = tokens.shape
        assert S + steps <= self.scfg.max_len
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)})
        out = []
        pos = jnp.int32(S)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok[:, 0]))
            logits, cache = self._decode(self.params, tok, cache, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            pos = pos + 1
        return np.stack(out, axis=1)


# ---------------------------------------------------------------------------
# OULD request admission/placement over a serving pool
# ---------------------------------------------------------------------------

class AdmissionController:
    """Epoch-based admission + placement for a serving pool.

    Strategy-agnostic: wraps any registered :class:`~repro.core.planner.
    Planner` (by name or instance) and feeds it one :class:`TopologyView`
    per admission round.  Stateful planners (``incremental``, warm
    ``ould-mp``) keep placements of persistent streams across rounds and
    cache constraint structure; stateless planners just get called.  One
    controller instance == one pool; per-round outages go through the
    view's ``alive`` mask.
    """

    def __init__(self, planner: Planner | str = "incremental",
                 **planner_options):
        self.planner: Planner = (get_planner(planner, **planner_options)
                                 if isinstance(planner, str) else planner)
        # Per-round solve stats only — a Plan pins its bound Problem (rate
        # matrices), which must not accumulate over a long-running pool.
        self.history: list[ResolveStats] = []

    def admit(self, problem: Problem, view: TopologyView | np.ndarray,
              request_ids=None) -> Plan:
        """Place this round's active request set; returns the :class:`Plan`.

        ``view`` may be a prepared TopologyView or a raw rate array (wrapped
        via :func:`make_view`); ``request_ids`` are stable stream ids for
        placement inheritance across rounds (ignored by stateless planners).
        """
        if isinstance(view, np.ndarray):
            view = make_view(view)
        plan = self.planner.plan(problem, view, request_ids=request_ids)
        self.history.append(plan.solve_stats or ResolveStats(
            0, plan.solution.n_admitted, problem.n_nodes, True,
            plan.solve_time_s))
        return plan

    @property
    def total_solve_time_s(self) -> float:
        return float(sum(s.solve_time_s for s in self.history))


def schedule_requests(cfg: ModelConfig, *, n_nodes: int, requests: int,
                      hbm_bytes: float, flops_budget: float,
                      rates_bits: np.ndarray, seq: int = 2048,
                      planner: str = "ould-dp",
                      **planner_options: Any) -> tuple[Plan, Any]:
    """Place R concurrent serving requests' layer groups over the pool —
    the paper's multi-request placement applied to inference serving, via
    any registered planner (``planner_options`` configure it, e.g.
    ``sparse_k`` for the pruned-DP strategies).  Returns
    (Plan, Evaluation)."""
    profile = lm_profile(
        cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_ff=cfg.d_ff, vocab=cfg.vocab,
        seq=seq, moe_experts=cfg.moe.num_experts if cfg.moe else 0,
        moe_topk=cfg.moe.top_k if cfg.moe else 0, window=cfg.window)
    sources = np.arange(requests) % n_nodes
    prob = Problem(profile, np.full(n_nodes, hbm_bytes),
                   np.full(n_nodes, flops_budget), rates_bits,
                   sources.astype(np.int64),
                   compute_speed=np.full(n_nodes, 197e12))
    plan = get_planner(planner, **planner_options).plan(
        prob, make_view(rates_bits))
    return plan, plan.evaluate()
