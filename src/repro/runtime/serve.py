"""Serving runtime: batched prefill + decode with OULD request scheduling.

The paper's scenario is R concurrent classification requests placed across
constrained nodes.  The serving loop mirrors it: incoming requests are
admitted/placed by OULD over the node pool (devices or UAVs), then executed
as batched prefill + decode steps with donated caches.  On CPU/tests this
runs the real model; the scheduling layer is topology-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import Problem, evaluate, solve_ould
from ..core.ould import IncrementalSolver, ResolveStats, Solution
from ..core.profiles import ModelProfile, lm_profile
from ..models import transformer
from . import steps as steps_mod


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 128
    batch_size: int = 4


class Server:
    """Minimal production-shaped server: admit → prefill → decode loop."""

    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self._prefill = jax.jit(steps_mod.make_prefill_step(
            cfg, max_len=scfg.max_len))
        self._decode = jax.jit(steps_mod.make_decode_step(cfg),
                               donate_argnums=(2,))

    def generate(self, tokens: np.ndarray, steps: int) -> np.ndarray:
        """tokens: (B, S) prompt → (B, steps) generated ids (greedy)."""
        B, S = tokens.shape
        assert S + steps <= self.scfg.max_len
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)})
        out = []
        pos = jnp.int32(S)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok[:, 0]))
            logits, cache = self._decode(self.params, tok, cache, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            pos = pos + 1
        return np.stack(out, axis=1)


# ---------------------------------------------------------------------------
# OULD request admission/placement over a serving pool
# ---------------------------------------------------------------------------

class AdmissionController:
    """Epoch-based admission + placement for a serving pool.

    Wraps :class:`~repro.core.ould.IncrementalSolver` so repeated admission
    rounds (the swarm simulator's epochs, or a pod's periodic re-placement
    after stragglers/failures) are warm-started: placements of streams that
    persist across rounds are kept unless the topology changed under them,
    and the ILP constraint structure is cached.  One controller instance ==
    one pool with fixed per-node capacities; per-round outages go through
    ``alive``.
    """

    def __init__(self, profile: ModelProfile, mem_cap: np.ndarray,
                 comp_cap: np.ndarray,
                 compute_speed: np.ndarray | None = None, *,
                 solver: str = "dp", rel_change: float = 0.05, **solver_kw):
        self._inc = IncrementalSolver(
            profile, mem_cap, comp_cap, compute_speed,
            solver=solver, rel_change=rel_change, **solver_kw)  # type: ignore[arg-type]
        self.history: list[ResolveStats] = []

    def admit(self, rates: np.ndarray, sources: np.ndarray,
              request_ids=None, alive: np.ndarray | None = None,
              cold: bool = False) -> tuple[Solution, ResolveStats]:
        """Place this round's active request set; returns (Solution, stats).

        ``request_ids`` are stable stream ids (placement inheritance across
        rounds); ``cold=True`` forces a from-scratch solve (the baseline the
        warm path is benchmarked against)."""
        fn = self._inc.solve if cold else self._inc.resolve
        sol, stats = fn(rates, sources, request_ids, alive)
        self.history.append(stats)
        return sol, stats

    @property
    def total_solve_time_s(self) -> float:
        return float(sum(s.solve_time_s for s in self.history))


def schedule_requests(cfg: ModelConfig, *, n_nodes: int, requests: int,
                      hbm_bytes: float, flops_budget: float,
                      rates_bits: np.ndarray, seq: int = 2048,
                      solver: str = "dp") -> tuple[Any, Any]:
    """Place R concurrent serving requests' layer groups over the pool —
    the paper's multi-request OULD applied to inference serving.  Returns
    (Solution, Evaluation)."""
    profile = lm_profile(
        cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_ff=cfg.d_ff, vocab=cfg.vocab,
        seq=seq, moe_experts=cfg.moe.num_experts if cfg.moe else 0,
        moe_topk=cfg.moe.top_k if cfg.moe else 0, window=cfg.window)
    sources = np.arange(requests) % n_nodes
    prob = Problem(profile, np.full(n_nodes, hbm_bytes),
                   np.full(n_nodes, flops_budget), rates_bits,
                   sources.astype(np.int64),
                   compute_speed=np.full(n_nodes, 197e12))
    sol = solve_ould(prob, solver=solver)  # type: ignore[arg-type]
    return sol, evaluate(prob, sol)
