"""Elastic scaling + degradation-aware re-placement.

The paper's OULD-MP exists because the *topology changes under the
computation* (UAVs move, links fade, nodes drop).  The TPU analogue: chips
fail, pods get preempted, stragglers appear.  This module maps those events
onto the same machinery:

* ``plan_elastic_mesh`` — given the surviving device count, pick the largest
  valid (data, model) mesh and the re-shard plan (restore checkpoints with
  new shardings — CheckpointManager.restore does the placement).
* ``replan_placement`` — re-solve OULD with degraded capacities: a straggler
  node gets its compute capacity scaled by its observed slowdown, a failed
  node gets capacity 0, links inherit measured bandwidths.  This IS the
  paper's technique (Problem/solve_ould) driving the serving runtime's stage
  re-placement — one code path for UAVs and pods.
* ``predictive_replan`` — OULD-MP over a *forecast* of capacities (e.g. a
  node with rising ECC errors degrades over the horizon), yielding one
  placement valid across the predicted window instead of re-solving per
  event (Fig. 13/14 semantics on the pod).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import Problem, solve_ould
from ..core.placement import Stage, to_stages
from ..core.profiles import ModelProfile
from ..core.radio import TpuLinkModel


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def plan_elastic_mesh(n_devices: int, *, model_parallel: int = 16,
                      min_data: int = 1) -> ElasticPlan:
    """Largest (data, model) grid on the survivors, keeping TP intact when
    possible (params reshard is cheap across data, expensive across model)."""
    m = model_parallel
    while m > 1 and n_devices // m < min_data:
        m //= 2
    d = n_devices // m
    if d < 1:
        raise ValueError(f"no valid mesh for {n_devices} devices")
    return ElasticPlan(data=d, model=m)


def replan_placement(profile: ModelProfile, *, n_groups: int,
                     hbm_bytes: float, flops_budget: float,
                     slowdown: np.ndarray | None = None,
                     failed: np.ndarray | None = None,
                     link: TpuLinkModel | None = None,
                     solver: str = "ilp") -> list[Stage]:
    """One-shot OULD re-solve with degraded capacities (straggler/failure)."""
    link = link or TpuLinkModel()
    comp = np.full(n_groups, flops_budget, float)
    mem = np.full(n_groups, hbm_bytes, float)
    if slowdown is not None:
        comp = comp / np.maximum(slowdown, 1.0)
    if failed is not None:
        comp[failed] = 0.0
        mem[failed] = 0.0
    coords = np.stack([np.arange(n_groups) % link.torus[0],
                       np.arange(n_groups) // link.torus[0]], -1)
    rho = link.rate_matrix(coords, np.zeros(n_groups, np.int64))
    prob = Problem(profile, mem, comp, rho * 8.0, np.zeros(1, np.int64))
    sol = solve_ould(prob, solver=solver)  # type: ignore[arg-type]
    if not sol.admitted[0]:
        raise ValueError("no feasible placement on surviving capacity")
    return to_stages(sol.assign[0])


def predictive_replan(profile: ModelProfile, *, n_groups: int,
                      hbm_bytes: float, flops_budget: float,
                      predicted_slowdown: np.ndarray,
                      link: TpuLinkModel | None = None,
                      solver: str = "ilp") -> list[Stage]:
    """OULD-MP on the pod: ``predicted_slowdown`` is (T, N) — e.g. a failing
    node's forecast degradation.  Rates are modulated per-step so the chosen
    placement avoids nodes that are *about to* degrade (the paper's
    disconnection-avoidance argument, Fig. 13)."""
    link = link or TpuLinkModel()
    T, N = predicted_slowdown.shape
    assert N == n_groups
    coords = np.stack([np.arange(n_groups) % link.torus[0],
                       np.arange(n_groups) // link.torus[0]], -1)
    base = link.rate_matrix(coords, np.zeros(n_groups, np.int64))
    rates = np.zeros((T, N, N))
    for t in range(T):
        # a slowed node drains its links' effective bandwidth too
        f = 1.0 / np.maximum(predicted_slowdown[t], 1.0)
        rates[t] = base * np.minimum(f[:, None], f[None, :])
    comp = np.full(n_groups, flops_budget) / np.maximum(
        predicted_slowdown.max(axis=0), 1.0)
    prob = Problem(profile, np.full(n_groups, hbm_bytes), comp, rates * 8.0,
                   np.zeros(1, np.int64))
    sol = solve_ould(prob, solver=solver)  # type: ignore[arg-type]
    if not sol.admitted[0]:
        raise ValueError("no feasible predictive placement")
    return to_stages(sol.assign[0])
