"""Multi-process transport: one JAX process per simulated node group.

SNIPPETS.md §2's multi-controller model — each process owns its local
devices and must be launched explicitly — scaled down to one machine: every
worker is a full JAX process (``repro.transport.worker --jax``) and each
shipped activation is put on the worker's default device before being
echoed, so the bytes cross a process boundary *and* a host→device buffer
copy on the receiving side (device-to-device movement where the platform
provides it; on the CPU backend this is the host↔device-buffer copy pair).

Node → process ownership follows the swarm's mobility groups when a
``group_of`` array is supplied (one JAX process per group, the SNIPPETS §2
"one process per host" unit), else round-robin over ``n_workers``.
"""

from __future__ import annotations

import numpy as np

from .loopback import LoopbackTransport


class MultiProcTransport(LoopbackTransport):
    name = "multiproc"
    _jax_workers = True

    def __init__(self, *, n_workers: int | None = None,
                 group_of: np.ndarray | None = None,
                 timeout_s: float = 300.0):
        node_of = None
        if group_of is not None:
            group_of = np.asarray(group_of, np.int64)
            n_groups = int(group_of.max()) + 1 if group_of.size else 1
            n_workers = n_workers if n_workers is not None else n_groups
            node_of = {int(i): int(g) for i, g in enumerate(group_of)}
        super().__init__(n_workers=n_workers or 2, node_of=node_of,
                         timeout_s=timeout_s)
