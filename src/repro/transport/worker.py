"""Standalone transport worker process + the framed socket protocol.

One worker == one OS process standing in for a simulated UAV node (or node
group).  The parent (:class:`~repro.transport.loopback.LoopbackTransport`)
listens on a localhost TCP socket, spawns workers with ``--connect`` pointing
back at it, and ships activation buffers through them — real serialization,
a real kernel-mediated copy, and a real second address space, which is what
the modeled-delay path never exercised.

Protocol (both directions): ``op`` (1 byte) + ``length`` (8 bytes, ``<Q``)
+ payload.

======  =====================================================================
op      meaning
======  =====================================================================
``H``   worker → parent hello on connect: JSON ``{"pid": …, "backend": …}``
``S``   parent → worker: ship this buffer to the worker's node
``R``   worker → parent: a 16-byte timing header (``<2d``: seconds the
        worker spent draining the payload off the socket, seconds in the
        echo/device hop) followed by the shipped buffer, back from the
        worker's memory
``Q``   parent → worker: shut down (no reply)
======  =====================================================================

The ``R`` timing header is what lets the parent reconstruct *worker-side*
spans (``worker_recv``/``worker_echo`` on the ``transport_worker`` track):
the worker has no shared clock with the parent, so it reports durations and
the parent tail-aligns them against its own receive time.

In ``--jax`` mode (:class:`MultiProcTransport`) the worker is a real JAX
process: each shipped buffer is put on the worker's default device before
being echoed, so the bytes cross process *and* device-buffer boundaries.
The plain mode deliberately imports nothing heavy — loopback workers must
start in milliseconds, since churn-rejoin spawns them mid-scenario.
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import struct
import sys
import time

_LEN = struct.Struct("<Q")
# OP_REPLY timing header: (recv_s, echo_s) — durations, not timestamps
# (worker and parent clocks are unrelated; the parent tail-aligns).
REPLY_TIMES = struct.Struct("<2d")

OP_HELLO = b"H"
OP_SHIP = b"S"
OP_REPLY = b"R"
OP_QUIT = b"Q"


def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ConnectionError on EOF."""
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ConnectionError("transport peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def send_frame(sock: socket.socket, op: bytes, payload: bytes = b"") -> None:
    sock.sendall(op + _LEN.pack(len(payload)))
    if payload:
        sock.sendall(payload)


def recv_frame(sock: socket.socket) -> tuple[bytes, bytes]:
    head = recv_exact(sock, 1 + _LEN.size)
    op, (n,) = head[:1], _LEN.unpack(head[1:])
    return op, (recv_exact(sock, n) if n else b"")


def _echo(payload: bytes, device_put) -> bytes:
    """The worker-side hop: host bytes → (optionally a device buffer) → host
    bytes.  Returns the exact same byte string — fidelity is asserted by the
    parent, not assumed."""
    if device_put is None:
        return payload
    return device_put(payload)


def _jax_device_put():
    """Build the ``--jax`` echo hop lazily (imports jax)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    def put(payload: bytes) -> bytes:
        buf = np.frombuffer(payload, dtype=np.uint8)
        dev = jax.device_put(jnp.asarray(buf))      # host → device buffer
        return np.asarray(jax.block_until_ready(dev)).tobytes()

    return put


def serve(host: str, port: int, *, use_jax: bool) -> None:
    device_put = None
    backend = None
    if use_jax:
        import jax
        device_put = _jax_device_put()
        backend = jax.devices()[0].platform
    sock = socket.create_connection((host, port), timeout=60.0)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    hello = json.dumps({"pid": os.getpid(), "backend": backend}).encode()
    send_frame(sock, OP_HELLO, hello)
    try:
        while True:
            # Header first, payload timed separately: the blocking wait for
            # the *next* request is idle time and must not be charged to
            # recv_s (only the drain of an announced payload is).
            head = recv_exact(sock, 1 + _LEN.size)
            op, (n,) = head[:1], _LEN.unpack(head[1:])
            if op == OP_SHIP:
                t0 = time.perf_counter()
                payload = recv_exact(sock, n) if n else b""
                t1 = time.perf_counter()
                echoed = _echo(payload, device_put)
                t2 = time.perf_counter()
                send_frame(sock, OP_REPLY,
                           REPLY_TIMES.pack(t1 - t0, t2 - t1) + echoed)
            elif op == OP_QUIT:
                return
            else:
                payload = recv_exact(sock, n) if n else b""
                raise ValueError(f"transport worker: unknown op {op!r}")
    except ConnectionError:
        pass        # parent died or closed; nothing left to serve
    finally:
        sock.close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="repro transport worker")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--jax", action="store_true",
                    help="route shipped buffers through a JAX device buffer")
    args = ap.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    serve(host, int(port), use_jax=args.jax)


if __name__ == "__main__":
    sys.exit(main())
