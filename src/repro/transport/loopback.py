"""Loopback transport: real activation bytes through real OS processes.

The parent spawns ``n_workers`` worker processes (``repro.transport.worker``)
and connects each over localhost TCP.  Shipping an activation from node
``src`` to node ``dst``:

1. materialize the device array on host and serialize it (contiguous copy),
2. send the bytes to the worker process owning ``dst`` (length-prefixed
   frame: the payload crosses two kernel socket buffers and lives briefly
   in a second address space),
3. receive the echoed bytes back and reconstruct the array the consuming
   stage reads — so downstream correctness *depends on* transport fidelity
   rather than being assumed.

The measured wall covers the full hop (serialize + round trip +
reconstruct); realized bandwidth is charged conservatively as
``payload / wall``.  Node → worker ownership defaults to round-robin and
accepts an explicit ``node_of`` map (the multi-proc backend maps by mobility
group).
"""

from __future__ import annotations

import os
import socket
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np

from .base import ShipResult, TransportBase, WorkerStats
from .worker import (OP_HELLO, OP_QUIT, OP_REPLY, OP_SHIP, REPLY_TIMES,
                     recv_frame, send_frame)


class LoopbackTransport(TransportBase):
    name = "loopback"
    _jax_workers = False      # MultiProcTransport flips this

    def __init__(self, *, n_workers: int = 2,
                 node_of: dict[int, int] | None = None,
                 timeout_s: float = 120.0):
        super().__init__()
        if n_workers < 1:
            raise ValueError("loopback transport needs at least one worker")
        self.n_workers = int(n_workers)
        self._node_of = dict(node_of) if node_of else None
        self._timeout_s = float(timeout_s)
        self._procs: list[subprocess.Popen] = []
        self._conns: list[socket.socket] = []
        self.worker_pids: list[int] = []
        self.worker_backends: list[str | None] = []
        # Worker-side timing shipped back in every OP_REPLY header,
        # accumulated per worker index (the obs per-worker track's source).
        self.worker_stats: dict[int, WorkerStats] = {}

    # -- lifecycle -----------------------------------------------------------
    @property
    def started(self) -> bool:
        return bool(self._conns)

    def start(self) -> None:
        if self.started:
            return
        import json

        server = socket.create_server(("127.0.0.1", 0))
        server.settimeout(self._timeout_s)
        port = server.getsockname()[1]
        # worker.py runs as a plain script: stdlib-only startup (no package
        # import), so plain workers come up in milliseconds — churn rejoin
        # spawns them mid-scenario.
        cmd = [sys.executable, str(Path(__file__).with_name("worker.py")),
               "--connect", f"127.0.0.1:{port}"]
        if self._jax_workers:
            cmd.append("--jax")
        try:
            for _ in range(self.n_workers):
                self._procs.append(subprocess.Popen(cmd, env=dict(os.environ)))
            for _ in range(self.n_workers):
                conn, _ = server.accept()
                conn.settimeout(self._timeout_s)
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                op, payload = recv_frame(conn)
                if op != OP_HELLO:
                    raise ConnectionError(f"expected worker hello, got {op!r}")
                hello = json.loads(payload)
                self._conns.append(conn)
                self.worker_pids.append(int(hello["pid"]))
                self.worker_backends.append(hello.get("backend"))
        except Exception:
            self.close()
            raise
        finally:
            server.close()

    def close(self) -> None:
        for conn in self._conns:
            try:
                send_frame(conn, OP_QUIT)
            except OSError:
                pass
            conn.close()
        self._conns = []
        for p in self._procs:
            try:
                p.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                p.kill()
        self._procs = []

    # -- shipping ------------------------------------------------------------
    def worker_of(self, node: int) -> int:
        if self._node_of is not None and node in self._node_of:
            return self._node_of[node] % self.n_workers
        return node % self.n_workers

    def ship(self, src_node: int, dst_node: int, array) -> ShipResult:
        if not self.started:
            self.start()
        worker = self.worker_of(dst_node)
        conn = self._conns[worker]
        t0 = time.perf_counter()
        host = np.ascontiguousarray(np.asarray(jax.block_until_ready(array)))
        payload = host.tobytes()
        send_frame(conn, OP_SHIP, payload)
        op, reply = recv_frame(conn)
        if op != OP_REPLY or len(reply) != len(payload) + REPLY_TIMES.size:
            raise ConnectionError(
                f"transport worker returned {op!r}/{len(reply)}B "
                f"for a {len(payload)}B shipment")
        recv_s, echo_s = REPLY_TIMES.unpack_from(reply)
        out = np.frombuffer(reply, dtype=host.dtype,
                            offset=REPLY_TIMES.size).reshape(host.shape)
        wall = time.perf_counter() - t0
        self._record(src_node, dst_node, len(payload), wall)
        self._record_worker(worker, recv_s, echo_s)
        self.moved_bytes += len(payload)
        return ShipResult(out, len(payload), wall, moved=True)

    def _record_worker(self, worker: int, recv_s: float,
                       echo_s: float) -> None:
        ws = self.worker_stats.setdefault(worker, WorkerStats())
        ws.n += 1
        ws.recv_s += recv_s
        ws.echo_s += echo_s
        if self._tracer.enabled:
            # The worker reports durations only (no shared clock); tail-
            # align against our receive time: the echo ended just before
            # the reply hit our socket, the drain just before the echo.
            tr = self._tracer
            now = tr.now()
            track = tr.track("transport_worker")
            tr.span(track, "worker_recv", now - echo_s - recv_s, recv_s,
                    lane=worker, a0=recv_s)
            tr.span(track, "worker_echo", now - echo_s, echo_s,
                    lane=worker, a0=echo_s)
