"""Byte-moving transport backends for the execution engine (DESIGN.md §7).

================  ==========================================================
backend           what a transfer costs
================  ==========================================================
``inproc``        modeled link delay + measured host serialization — the
                  pre-transport path, bit-compatible default
``loopback``      real serialization + kernel socket copy to a worker OS
                  process and back; the consuming stage reads the
                  reconstructed bytes
``multiproc``     loopback where every worker is a JAX process (one per
                  node group, SNIPPETS §2) that lands the buffer on its
                  device before echoing
================  ==========================================================
"""

from __future__ import annotations

import numpy as np

from .base import (LinkStats, ShipResult, Transport, TransportBase,
                   WorkerStats)
from .inproc import InProcTransport
from .loopback import LoopbackTransport
from .multiproc import MultiProcTransport

TRANSPORTS = ("inproc", "loopback", "multiproc")


def make_transport(name: str, *, n_workers: int = 2,
                   group_of: np.ndarray | None = None) -> Transport:
    """Build a backend by registry name (the ``--transport`` CLI values)."""
    if name == "inproc":
        return InProcTransport()
    if name == "loopback":
        return LoopbackTransport(n_workers=n_workers)
    if name == "multiproc":
        return MultiProcTransport(
            n_workers=None if group_of is not None else n_workers,
            group_of=group_of)
    raise ValueError(f"unknown transport {name!r}; one of {TRANSPORTS}")


__all__ = [
    "InProcTransport", "LinkStats", "LoopbackTransport", "MultiProcTransport",
    "ShipResult", "TRANSPORTS", "Transport", "TransportBase",
    "WorkerStats",
    "make_transport",
]
