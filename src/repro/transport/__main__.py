"""Transport smoke CLI — the CI loopback check.

    PYTHONPATH=src python -m repro.transport --workers 2 --mb 4

Ships random tensors through worker OS processes, asserts byte-exact
reconstruction, and prints realized bandwidth + the worker PIDs (which must
differ from the parent's — that is the "real processes" claim, checked, not
assumed).  ``--multiproc`` runs the JAX-worker backend instead.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from . import LoopbackTransport, MultiProcTransport


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="transport loopback smoke")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mb", type=float, default=4.0,
                    help="payload size per shipment")
    ap.add_argument("--ships", type=int, default=4)
    ap.add_argument("--multiproc", action="store_true",
                    help="JAX worker processes (device hop) instead of plain")
    args = ap.parse_args(argv)

    cls = MultiProcTransport if args.multiproc else LoopbackTransport
    rng = np.random.default_rng(0)
    n = max(1, int(args.mb * 1e6 / 4))
    with cls(n_workers=args.workers) as tp:
        pids = set(tp.worker_pids)
        assert os.getpid() not in pids, "worker ran in the parent process"
        assert len(pids) == args.workers, f"expected {args.workers} processes"
        for i in range(args.ships):
            x = rng.standard_normal(n).astype(np.float32)
            res = tp.ship(i % args.workers, (i + 1) % args.workers, x)
            if not np.array_equal(np.asarray(res.array), x):
                print("FAIL: shipped tensor came back different", file=sys.stderr)
                return 1
        moved = tp.moved_bytes / 1e6
        bw = [f"{s}->{d}: {ls.bytes_per_s / 1e6:.0f} MB/s"
              for (s, d), ls in sorted(tp.link_stats.items())]
        print(f"[transport] {tp.name}: {args.ships} shipments, "
              f"{moved:.1f} MB moved through {len(pids)} worker processes "
              f"(pids {sorted(pids)}, parent {os.getpid()})")
        print(f"[transport] realized bandwidth: {', '.join(bw)}")
        if args.multiproc:
            print(f"[transport] worker backends: {tp.worker_backends}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
