"""In-process transport: the modeled-delay path, bit-compatible default.

This is exactly what the pre-transport engine did per transfer — block until
the producer's activation is ready, copy it to host (the observable
serialization cost of a U2U shipment on this substrate), and hand the
*original* device array to the consuming stage.  No bytes leave the process;
the link delay stays the analytic ``nbytes × spb`` term the planner priced.
Keeping the returned array identical to the input is what makes an engine
with the default transport bitwise-equal to the pre-transport engine.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from .base import ShipResult, TransportBase


class InProcTransport(TransportBase):
    name = "inproc"

    def ship(self, src_node: int, dst_node: int, array) -> ShipResult:
        t0 = time.perf_counter()
        host = np.asarray(jax.block_until_ready(array))
        wall = time.perf_counter() - t0
        self._record(src_node, dst_node, host.nbytes, wall)
        return ShipResult(array, int(host.nbytes), wall, moved=False)
