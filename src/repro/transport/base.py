"""The Transport contract: how activation bytes cross a link (DESIGN.md §7).

The execution engine routes every :class:`~repro.exec.stage_graph.Transfer`
through one of these backends.  A backend does three things per shipment:

1. **materialize** the activation off the device (real serialization),
2. **move** it — or not: the in-proc backend is the modeled-delay path —
   and hand back the array the consuming stage should read,
3. **measure** the wall of the whole hop and accumulate it per directed
   link, so :func:`repro.exec.calibrate.calibrated_problem` can turn
   realized seconds/byte into calibrated rates for a planner re-solve.

The contract is deliberately synchronous and per-transfer: the engine's
topological tick loop already orders producer before consumer, and the paper
prices each boundary shipment independently (Eq. 14 sums per-link terms).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from ..obs import NULL_TRACER, TRANSPORT


@dataclasses.dataclass(frozen=True)
class ShipResult:
    """One completed shipment."""

    array: object          # what the consuming stage reads (device or host)
    nbytes: int            # payload bytes materialized for this hop
    wall_s: float          # measured wall of the whole hop
    moved: bool            # True iff the bytes left this process


@dataclasses.dataclass
class LinkStats:
    """Accumulated realized samples of one directed link."""

    n: int = 0
    nbytes: float = 0.0
    wall_s: float = 0.0

    @property
    def bytes_per_s(self) -> float:
        return self.nbytes / self.wall_s if self.wall_s > 0 else float("inf")

    @property
    def seconds_per_byte(self) -> float:
        return self.wall_s / self.nbytes if self.nbytes > 0 else 0.0


@dataclasses.dataclass
class WorkerStats:
    """Accumulated worker-side timing for one worker process, as reported
    in OP_REPLY headers: seconds draining payloads off the socket and
    seconds in the echo/device hop (durations, worker clock)."""

    n: int = 0
    recv_s: float = 0.0
    echo_s: float = 0.0


@runtime_checkable
class Transport(Protocol):
    """A byte-moving backend the engine can route transfers through."""

    name: str
    link_stats: dict[tuple[int, int], LinkStats]

    def ship(self, src_node: int, dst_node: int, array) -> ShipResult: ...

    def close(self) -> None: ...


class TransportBase:
    """Shared telemetry: per-link realized bandwidth accounting."""

    name = "base"

    def __init__(self):
        self.link_stats: dict[tuple[int, int], LinkStats] = {}
        self.moved_bytes: float = 0.0   # bytes that actually left the process
        self._tracer = NULL_TRACER

    def set_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer`: every recorded shipment emits
        one TRANSPORT span (real-time domain, ``tracer.now()``) with payload
        bytes and realized bandwidth as args.  All backends funnel through
        :meth:`_record`, so this is the single emission point — the engine
        and the swarm's substrate-sampling path never double-emit."""
        self._tracer = tracer if tracer is not None else NULL_TRACER
        if self._tracer.enabled:
            self._tracer.intern("ship", "nbytes", "bytes_per_s")
            # Worker-process backends emit these on the per-worker
            # "transport_worker" track (lane = worker index).
            self._tracer.intern("worker_recv", "recv_s")
            self._tracer.intern("worker_echo", "echo_s")

    def _record(self, src: int, dst: int, nbytes: int, wall_s: float) -> None:
        ls = self.link_stats.setdefault((src, dst), LinkStats())
        ls.n += 1
        ls.nbytes += nbytes
        ls.wall_s += wall_s
        if self._tracer.enabled:
            self._tracer.span(
                TRANSPORT, "ship", self._tracer.now() - wall_s, wall_s,
                lane=src, a0=float(nbytes),
                a1=nbytes / wall_s if wall_s > 0 else float("inf"))

    def measured_spb(self, n_nodes: int) -> np.ndarray:
        """(N, N) realized seconds/byte; NaN where the link was never
        sampled — the comm-calibration twin of ``measured_layer_seconds``."""
        spb = np.full((n_nodes, n_nodes), np.nan)
        for (s, d), ls in self.link_stats.items():
            if s < n_nodes and d < n_nodes and ls.nbytes > 0:
                spb[s, d] = ls.seconds_per_byte
        return spb

    def link_seconds_per_byte(self) -> dict[tuple[int, int], float]:
        """Sampled links only — what :func:`calibrate_rates` consumes."""
        return {k: ls.seconds_per_byte for k, ls in self.link_stats.items()
                if ls.nbytes > 0}

    def start(self) -> None:        # backends with processes override
        pass

    def close(self) -> None:
        pass

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.close()
        return False
