from .sharding import (MeshAxes, active_mesh, batch_spec, cache_pspec,
                       param_pspecs, set_active_mesh, with_dp_constraint)

__all__ = ["MeshAxes", "active_mesh", "batch_spec", "cache_pspec",
           "param_pspecs", "set_active_mesh", "with_dp_constraint"]
