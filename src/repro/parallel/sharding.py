"""GSPMD sharding rules: name-pattern → PartitionSpec with divisibility guards.

Strategy (DESIGN.md §4): 2-D "fsdp × tensor".  Parameters shard their
feature dims on the ``model`` axis (TP / EP) and, for FSDP, a second dim on
``data`` (+ ``pod`` when the multi-pod mesh is active and the arch is large).
Every rule is *validated against the actual dim sizes* — any mesh axis that
does not divide its dim is dropped (GSPMD would error otherwise), so the same
rule table serves all 10 architectures.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis-name bundles for the active mesh."""
    data: tuple[str, ...] = ("data",)   # ("pod","data") on the multi-pod mesh
    model: str = "model"

    @property
    def dp(self) -> tuple[str, ...]:
        return self.data


# Pattern table: (regex on param path, spec builder).  Specs are expressed per
# *unstacked* dims; a leading layer-stack dim (from scan-stacked blocks) is
# detected by the caller and padded with None.
#   d = d_model-like dim → FSDP ('data'), f = feature/out dim → TP ('model'),
#   E = expert dim → EP ('model').
_RULES: list[tuple[str, list[str | None]]] = [
    (r"embed/table$",          ["model", "data"]),   # (V, d)
    (r"lm_head$",              ["data", "model"]),   # (d, V)
    (r"(attn|mla)/(wq|wk|wv|wqkv|wkv|wq_a|wq_b|wkv_a|wkv_b)$",
                               ["data", "model"]),
    (r"(attn|mla)/wo$",        ["model", "data"]),
    (r"mlp/(w_in|w_gate)$",    ["data", "model"]),   # (d, f)
    (r"mlp/w_out$",            ["model", "data"]),   # (f, d)
    (r"moe/router$",           ["data", None]),      # (d, E)
    (r"moe/(w_in|w_gate)$",    ["model", "data", None]),  # (E, d, f) — EP
    (r"moe/w_out$",            ["model", None, "data"]),  # (E, f, d)
    (r"(ssm|mlstm)/(w_x|w_z|w_bc|w_dt|w_qkv|w_up|w_gates)$",
                               ["data", "model"]),
    (r"(ssm|mlstm|slstm)/w_out$", ["model", "data"]),
    (r"slstm/w$",              ["data", "model"]),
    (r"slstm/r$",              [None, None, None]),
    (r"conv$",                 [None, None]),
    (r"norm\w*/scale$",        [None]),
    (r"bias$",                 [None]),
    (r"(A_log|dt_bias|D)$",    [None]),
]


def _axis_size(mesh: Mesh, name: str | None, axes: MeshAxes) -> int:
    if name is None:
        return 1
    if name == "data":
        s = 1
        for a in axes.dp:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axes.model]


def _to_spec(names: list[str | None], shape: tuple[int, ...], mesh: Mesh,
             axes: MeshAxes, fsdp: bool) -> P:
    """Map logical names to mesh axes, dropping non-dividing ones."""
    out: list[Any] = []
    offset = len(shape) - len(names)
    assert offset >= 0, (names, shape)
    out.extend([None] * offset)  # leading stacked-layer dims: replicated
    for k, nm in enumerate(names):
        dim = shape[offset + k]
        if nm == "data_model":  # shard over every axis (data ∪ model)
            full = tuple(axes.dp) + (axes.model,)
            size = 1
            for a in full:
                size *= mesh.shape[a]
            out.append(full if dim % size == 0 else None)
        elif nm == "model":
            out.append(axes.model if dim % mesh.shape[axes.model] == 0 else None)
        elif nm == "data":
            if not fsdp:
                out.append(None)
                continue
            size = _axis_size(mesh, "data", axes)
            if dim % size == 0:
                out.append(axes.dp if len(axes.dp) > 1 else axes.dp[0])
            elif dim % mesh.shape[axes.dp[-1]] == 0:
                out.append(axes.dp[-1])  # shard on intra-pod data only
            else:
                out.append(None)
        else:
            out.append(None)
    # GSPMD forbids using one mesh axis twice in a spec; drop later dup.
    seen: set[str] = set()
    clean: list[Any] = []
    for s in out:
        flat = s if isinstance(s, tuple) else ((s,) if s else ())
        if any(a in seen for a in flat):
            clean.append(None)
        else:
            seen.update(flat)
            clean.append(s)
    return P(*clean)


def param_pspecs(params: Any, mesh: Mesh, axes: MeshAxes | None = None,
                 *, fsdp: bool = True) -> Any:
    """PartitionSpec pytree mirroring ``params`` (dict-of-dict with leaf
    ndarrays / ShapeDtypeStructs)."""
    axes = axes or MeshAxes()

    def visit(path: str, node: Any) -> Any:
        if isinstance(node, dict):
            return {k: visit(f"{path}/{k}" if path else k, v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [visit(path, v) for v in node]
            return type(node)(out) if isinstance(node, tuple) else out
        shape = node.shape
        for pat, names in _RULES:
            if re.search(pat, path):
                return _to_spec(list(names), shape, mesh, axes, fsdp)
        # default: try FSDP on the largest dim if it divides
        if len(shape) >= 2:
            names = [None] * len(shape)
            big = max(range(len(shape)), key=lambda i: shape[i])
            specs: list[str | None] = [None] * len(shape)
            specs[big] = "model" if shape[big] % mesh.shape[axes.model] == 0 else None
            return _to_spec(specs, shape, mesh, axes, fsdp)
        return P()

    return visit("", params)


def batch_spec(axes: MeshAxes | None = None, *, batch_divisible: bool = True,
               ndim: int = 2) -> P:
    """Inputs (B, S, ...) — batch over (pod, data) when divisible."""
    axes = axes or MeshAxes()
    b = (axes.dp if len(axes.dp) > 1 else axes.dp[0]) if batch_divisible else None
    return P(b, *([None] * (ndim - 1)))


def cache_pspec(n_kv: int, batch: int, mesh: Mesh,
                axes: MeshAxes | None = None) -> P:
    """KV cache (L, B, S, n_kv, hd): batch→data when divisible, kv-heads→model
    when divisible, else sequence→model (decode context parallelism)."""
    axes = axes or MeshAxes()
    dsize = _axis_size(mesh, "data", axes)
    b = (axes.dp if len(axes.dp) > 1 else axes.dp[0]) if batch % dsize == 0 else None
    if n_kv % mesh.shape[axes.model] == 0:
        return P(None, b, None, axes.model, None)
    return P(None, b, axes.model, None, None)


# --- active mesh context (set by the launcher; absent on single-device) ----
_ACTIVE: dict[str, Any] = {"mesh": None, "axes": MeshAxes()}


def set_active_mesh(mesh: Mesh | None, axes: MeshAxes | None = None) -> None:
    _ACTIVE["mesh"] = mesh
    _ACTIVE["axes"] = axes or MeshAxes()


def active_mesh() -> tuple[Mesh | None, MeshAxes]:
    return _ACTIVE["mesh"], _ACTIVE["axes"]


def constrain(x: jax.Array, names: tuple[str | None, ...]) -> jax.Array:
    """Constrain by logical names ('data'/'model'/None per dim) with
    divisibility guards.  No-op when no production mesh is active."""
    mesh, axes = active_mesh()
    if mesh is None:
        return x
    spec = _to_spec(list(names), x.shape, mesh, axes, fsdp=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def with_dp_constraint(x: jax.Array, batch_divisible: bool = True) -> jax.Array:
    """Constrain an activation (B, S, d) to batch-sharded over DP axes.
    No-op when no production mesh is active (smoke tests, CPU)."""
    mesh, axes = active_mesh()
    if mesh is None:
        return x
    b = (axes.dp if len(axes.dp) > 1 else axes.dp[0]) if batch_divisible else None
    spec = P(b, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
