"""GPipe-style pipeline executor over a ``stage`` mesh axis (shard_map +
ppermute), with stage boundaries supplied by OULD placement.

The paper's placement runs layer ranges on different nodes and ships the
boundary activation over the best link; this is the same execution shape on
a TPU mesh: stage-stacked weights live on their stage's devices, microbatch
activations flow stage→stage via ``ppermute`` (the TPU-idiomatic point-to-
point the paper's U2U transfer maps onto — DESIGN.md §2).

Schedule: standard GPipe fill/drain — T = n_micro + n_stages − 1 ticks; at
each tick every stage runs one microbatch (bubble ticks run on zeros and
their outputs are discarded by the validity mask).  Uniform stages (equal
layer counts) keep the scan body static; OULD feeds this executor whenever
its stage cuts are uniform, and falls back to per-request placed execution
otherwise (runtime/serve.py path).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(block_fn: Callable, params_stacked, x, *, mesh: Mesh,
                     stage_axis: str = "stage", n_micro: int | None = None):
    """Run ``block_fn(params_slice, x_micro)`` as an S-stage pipeline.

    params_stacked: pytree with leading dim L (layers), L % n_stages == 0 —
    each stage executes its contiguous L/S slice per tick.
    x: (B, ...) global batch, B % n_micro == 0.
    Returns block-stack output equivalent to sequentially applying all L
    layers (validated in tests against the sequential reference).
    """
    n_stages = mesh.shape[stage_axis]
    B = x.shape[0]
    n_micro = n_micro or n_stages
    assert B % n_micro == 0
    mb = B // n_micro
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    assert L % n_stages == 0

    def stage_fn(p_local, x_all):
        """p_local: params slice (per_stage, ...); x_all: (B, ...) full batch
        (replicated); runs the fill/drain schedule for THIS stage."""
        sid = jax.lax.axis_index(stage_axis)
        micro = x_all.reshape(n_micro, mb, *x_all.shape[1:])
        T = n_micro + n_stages - 1

        def run_block(x_in):
            def body(h, p_slice):
                return block_fn(p_slice, h), None
            h, _ = jax.lax.scan(body, x_in, p_local)
            return h

        def tick(carry, t):
            buf, out = carry          # buf: (mb, ...) inbound activation
            m_idx = t - sid           # microbatch this stage works on
            valid = (m_idx >= 0) & (m_idx < n_micro)
            x_in = jnp.where(
                sid == 0,
                micro[jnp.clip(m_idx, 0, n_micro - 1)],
                buf)
            y = run_block(x_in)
            # last stage banks its result; others forward downstream
            out = jax.lax.cond(
                valid & (sid == n_stages - 1),
                lambda o: o.at[jnp.clip(m_idx, 0, n_micro - 1)].set(y),
                lambda o: o, out)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, out), None

        out0 = jnp.zeros_like(micro)
        buf0 = jnp.zeros_like(micro[0])
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(T))
        # only the last stage holds real outputs; psum-broadcast them
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)),
            stage_axis)
        return out.reshape(B, *x_all.shape[1:])

    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(P(stage_axis), P()),
                   out_specs=P(), check_rep=False)
    return fn(params_stacked, x)
