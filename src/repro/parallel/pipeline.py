"""GPipe-style pipeline executor over a ``stage`` mesh axis (shard_map +
ppermute), with stage boundaries supplied by OULD placement.

The paper's placement runs layer ranges on different nodes and ships the
boundary activation over the best link; this is the same execution shape on
a TPU mesh: stage-stacked weights live on their stage's devices, microbatch
activations flow stage→stage via ``ppermute`` (the TPU-idiomatic point-to-
point the paper's U2U transfer maps onto — DESIGN.md §2).

Schedule: standard GPipe fill/drain — T = n_micro + n_stages − 1 ticks; at
each tick every stage runs one microbatch (bubble ticks run on zeros and
their outputs are discarded by the validity mask).

Stage cuts may be **non-uniform** (:func:`pipeline_forward_stages`): each
stage's contiguous layer slice is padded to the longest stage's length and a
per-layer validity mask keeps the scan body static — padded slots re-run the
stage's last layer on a carried activation and the mask discards the result.
This is what lets OULD's real (rarely uniform) cuts run pipelined with
microbatches instead of falling back to per-request sequential execution
(DESIGN.md §5).  :func:`pipeline_forward` is the uniform special case.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _pad_stage_slices(params_stacked, stage_sizes: Sequence[int]):
    """Re-stack a leading-L pytree into (S, P_max, ...) padded stage slices.

    Padding repeats the stage's last layer: the padded slot's output is
    discarded by the validity mask, and re-running a real layer keeps the
    dummy computation numerically tame (no zero-weight NaN paths).
    """
    p_max = max(stage_sizes)
    starts = np.concatenate([[0], np.cumsum(stage_sizes)])[:-1]

    def pad_leaf(leaf):
        parts = []
        for start, size in zip(starts, stage_sizes):
            sl = leaf[start:start + size]
            if size < p_max:
                fill = jnp.broadcast_to(sl[-1:],
                                        (p_max - size,) + sl.shape[1:])
                sl = jnp.concatenate([sl, fill])
            parts.append(sl)
        return jnp.stack(parts)

    return jax.tree.map(pad_leaf, params_stacked), p_max


def pipeline_forward_stages(block_fn: Callable, params_stacked, x, *,
                            mesh: Mesh, stage_sizes: Sequence[int],
                            stage_axis: str = "stage",
                            n_micro: int | None = None):
    """Run ``block_fn(params_slice, x_micro)`` as a pipeline with arbitrary
    contiguous stage cuts.

    params_stacked: pytree with leading dim L (layers); ``stage_sizes`` are
    the per-stage layer counts (sum L, one per mesh stage, each ≥ 1) — e.g.
    ``[s.layer_end - s.layer_start for s in plan.stages(r)]`` for an OULD
    cut.  x: (B, ...) global batch, B % n_micro == 0.  Returns the
    block-stack output, equivalent to sequentially applying all L layers
    (validated in tests against the sequential reference, uniform and not).
    """
    n_stages = mesh.shape[stage_axis]
    sizes = list(int(s) for s in stage_sizes)
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    if len(sizes) != n_stages:
        raise ValueError(f"{len(sizes)} stage cuts on a {n_stages}-stage "
                         f"{stage_axis!r} mesh axis")
    if sum(sizes) != L or min(sizes) < 1:
        raise ValueError(f"stage_sizes {sizes} must partition L={L} layers "
                         "into non-empty contiguous slices")
    B = x.shape[0]
    n_micro = n_micro or n_stages
    assert B % n_micro == 0
    mb = B // n_micro

    padded, p_max = _pad_stage_slices(params_stacked, sizes)
    sizes_arr = jnp.asarray(sizes, jnp.int32)

    def stage_fn(p_local, sizes_all, x_all):
        """p_local: (1, P_max, ...) padded params slice; x_all: (B, ...) full
        batch (replicated); runs the fill/drain schedule for THIS stage."""
        sid = jax.lax.axis_index(stage_axis)
        p_local = jax.tree.map(lambda a: a[0], p_local)
        n_valid = sizes_all[sid]
        micro = x_all.reshape(n_micro, mb, *x_all.shape[1:])
        T = n_micro + n_stages - 1

        def run_block(x_in):
            def body(h, sl):
                p_slice, li = sl
                h_next = block_fn(p_slice, h)
                # padded slots carry h through unchanged (validity mask)
                return jnp.where(li < n_valid, h_next, h), None
            h, _ = jax.lax.scan(body, x_in,
                                (p_local, jnp.arange(p_max, dtype=jnp.int32)))
            return h

        def tick(carry, t):
            buf, out = carry          # buf: (mb, ...) inbound activation
            m_idx = t - sid           # microbatch this stage works on
            valid = (m_idx >= 0) & (m_idx < n_micro)
            x_in = jnp.where(
                sid == 0,
                micro[jnp.clip(m_idx, 0, n_micro - 1)],
                buf)
            y = run_block(x_in)
            # last stage banks its result; others forward downstream
            out = jax.lax.cond(
                valid & (sid == n_stages - 1),
                lambda o: o.at[jnp.clip(m_idx, 0, n_micro - 1)].set(y),
                lambda o: o, out)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, out), None

        out0 = jnp.zeros_like(micro)
        buf0 = jnp.zeros_like(micro[0])
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(T))
        # only the last stage holds real outputs; psum-broadcast them
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)),
            stage_axis)
        return out.reshape(B, *x_all.shape[1:])

    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(P(stage_axis), P(), P()),
                   out_specs=P(), check_rep=False)
    return fn(padded, sizes_arr, x)


def pipeline_forward(block_fn: Callable, params_stacked, x, *, mesh: Mesh,
                     stage_axis: str = "stage", n_micro: int | None = None):
    """Uniform-cut pipeline: L % n_stages == 0, each stage runs L/S layers.
    The historical entry point — now the trivial case of
    :func:`pipeline_forward_stages`."""
    n_stages = mesh.shape[stage_axis]
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    assert L % n_stages == 0
    return pipeline_forward_stages(
        block_fn, params_stacked, x, mesh=mesh,
        stage_sizes=[L // n_stages] * n_stages, stage_axis=stage_axis,
        n_micro=n_micro)
