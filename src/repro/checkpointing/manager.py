"""Fault-tolerant checkpointing: atomic, async, resumable, elastic.

Layout: <dir>/step_<n>/  with one .npy per pytree leaf (path-encoded names)
plus manifest.json (treedef, shapes, dtypes, step, data-pipeline cursor).
Writes go to step_<n>.tmp/ then os.replace → crash-safe (a partial write is
never visible).  ``AsyncCheckpointer`` snapshots to host memory synchronously
(cheap) and writes on a background thread so the train loop never blocks on
disk.  ``restore`` optionally re-shards onto a *different* mesh — the elastic
path: params saved on N devices restore cleanly on M ≠ N.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, path=()) -> list[tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _flatten(tree[k], path + (str(k),))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for i, v in enumerate(tree):
            out += _flatten(v, path + (str(i),))
        return out
    return [("/".join(path), tree)]


def _unflatten_like(template: Any, leaves: dict[str, Any], path=()) -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_like(template[k], leaves, path + (str(k),))
                for k in template}
    if isinstance(template, (list, tuple)):
        out = [_unflatten_like(v, leaves, path + (str(i),))
               for i, v in enumerate(template)]
        return type(template)(out) if isinstance(template, tuple) else out
    return leaves["/".join(path)]


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        leaves = _flatten(tree)
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            fn = name.replace("/", "__") + ".npy"
            np.save(tmp / fn, arr)
            manifest["leaves"][name] = {"file": fn, "shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """Restore into ``template``'s structure.  With ``shardings`` (a
        matching pytree of NamedSharding) leaves are device_put with the new
        sharding — the elastic re-shard path."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = {name: np.load(d / meta["file"])
                  for name, meta in manifest["leaves"].items()}
        tree = _unflatten_like(template, arrays)
        if shardings is not None:
            flat_s = _flatten(shardings)
            flat_t = dict(_flatten(tree))
            placed = {name: jax.device_put(flat_t[name], s)
                      for name, s in flat_s}
            tree = _unflatten_like(template, placed)
        return tree, manifest["extra"]


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk asynchronously."""

    def __init__(self, mgr: CheckpointManager):
        self.mgr = mgr
        self._thread: threading.Thread | None = None
        self._err: BaseException | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()                           # one in-flight write at a time
        # copy=True: np.asarray would alias host arrays and the caller may
        # mutate them (donated buffers) while the writer thread runs
        host = jax.tree.map(lambda x: np.array(x, copy=True), tree)

        def work():
            try:
                self.mgr.save(step, host, extra)
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
