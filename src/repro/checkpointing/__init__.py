from .manager import AsyncCheckpointer, CheckpointManager

__all__ = ["AsyncCheckpointer", "CheckpointManager"]
