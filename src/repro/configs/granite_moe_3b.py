"""granite-moe-3b-a800m — 32L d1536 24H(kv8) d_ff512 vocab49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite_moe_3b", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv=8, d_ff=512, vocab=49155,
    moe=MoEConfig(num_experts=40, top_k=8, impl="shard_map"),
)
