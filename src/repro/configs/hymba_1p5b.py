"""hymba-1.5b — 32L d1600 25H(kv5) d_ff5504 vocab32001, ssm_state=16,
parallel attn+mamba heads per block [arXiv:2411.13676; hf]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba_1p5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv=5, d_ff=5504, vocab=32001,
    ssm=SSMConfig(d_state=16), block_pattern=("hybrid",),
    subquadratic=True,  # SSM path carries long contexts; attn window-able
    window=1024, attn="swa",  # hymba uses mostly-SWA attention + meta tokens
)
