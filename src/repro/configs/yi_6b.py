"""yi-6b — 32L d4096 32H(kv4) d_ff11008 vocab64000, llama-arch GQA
[arXiv:2403.04652; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi_6b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=4, d_ff=11008, vocab=64000,
)
