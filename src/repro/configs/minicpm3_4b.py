"""minicpm3-4b — 62L d2560 40H(kv40) d_ff6400 vocab73448, MLA
[hf:openbmb/MiniCPM3-4B; hf]"""
from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3_4b", family="dense", n_layers=62, d_model=2560,
    n_heads=40, n_kv=40, d_ff=6400, vocab=73448, attn="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
)
