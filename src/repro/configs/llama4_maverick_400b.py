"""llama4-maverick-400b-a17b — 48L d5120 40H(kv8) d_ff8192 vocab202048,
MoE 128e top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv=8, d_ff=8192, vocab=202048,
    moe=MoEConfig(num_experts=128, top_k=1, impl="shard_map"),
)
