"""phi-3-vision-4.2b — 32L d3072 32H(kv32) d_ff8192 vocab32064, phi3-mini
backbone + CLIP frontend (stubbed: input_specs provides patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3_vision_4p2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32064, embed_stub=True,
)
