"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture (exact published config) plus the
paper's own CNN workloads (LeNet, VGG-16 — profile-level configs used by the
UAV benchmarks).
"""

from __future__ import annotations

import importlib

from .base import SHAPES, MLAConfig, ModelConfig, MoEConfig, ShapeConfig, SSMConfig

ARCH_IDS = (
    "granite_moe_3b",
    "llama4_maverick_400b",
    "musicgen_medium",
    "hymba_1p5b",
    "minicpm3_4b",
    "yi_6b",
    "h2o_danube3_4b",
    "internlm2_1p8b",
    "phi3_vision_4p2b",
    "xlstm_1p3b",
)

# canonical spec ids (with dashes) → module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "granite-moe-3b-a800m": "granite_moe_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "musicgen-medium": "musicgen_medium",
    "hymba-1.5b": "hymba_1p5b",
    "minicpm3-4b": "minicpm3_4b",
    "yi-6b": "yi_6b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "internlm2-1.8b": "internlm2_1p8b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "xlstm-1.3b": "xlstm_1p3b",
})


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    mod = importlib.import_module(f".{mod_name}", __name__)
    return mod.CONFIG


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS


__all__ = ["ARCH_IDS", "SHAPES", "MLAConfig", "ModelConfig", "MoEConfig",
           "SSMConfig", "ShapeConfig", "get_config", "list_archs"]
