"""xlstm-1.3b — 48L d2048 4H d_ff=0 vocab50304, sLSTM + mLSTM blocks (7:1)
[arXiv:2405.04517; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm_1p3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    ssm=SSMConfig(d_state=16),
    block_pattern=("mlstm",) * 7 + ("slstm",),
    subquadratic=True,
)
