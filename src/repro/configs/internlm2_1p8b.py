"""internlm2-1.8b — 24L d2048 16H(kv8) d_ff8192 vocab92544, GQA
[arXiv:2403.17297; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2_1p8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv=8, d_ff=8192, vocab=92544,
)
