"""Model / run configuration schema.

One ``ModelConfig`` per architecture (full production size) plus a
``reduced()`` shrink used by CPU smoke tests.  Shape suites (the assigned
input shapes) live in ``shapes.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

AttnKind = Literal["gqa", "mla", "swa", "none"]
BlockKind = Literal["attn", "mamba", "hybrid", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # 'scatter'   = sort/scatter grouped-matmul under GSPMD (baseline),
    # 'shard_map' = explicit-collective expert parallelism (§Perf winner;
    #               falls back to 'scatter' off-mesh or when E % TP != 0),
    # 'einsum'    = dense one-hot dispatch (tiny smoke configs / ablation)
    impl: Literal["scatter", "einsum", "shard_map"] = "scatter"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    expand: int = 2
    chunk: int = 256          # chunked-scan block length
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # moe | dense | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None      # default d_model // n_heads
    attn: AttnKind = "gqa"
    window: int | None = None        # SWA window
    rope_theta: float = 10_000.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    block_pattern: tuple[BlockKind, ...] = ("attn",)  # repeated over layers
    norm_eps: float = 1e-5
    # Embedding/head tables padded so the vocab dim shards on any production
    # mesh axis (16/32-way); pad logits are masked to -inf (exactness kept).
    vocab_pad_to: int = 512
    tie_embeddings: bool = False
    embed_stub: bool = False         # audio/vlm: train inputs are embeddings
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # long_500k applicability (sub-quadratic attention available?)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    def pattern_for_layers(self) -> tuple[BlockKind, ...]:
        p = self.block_pattern
        reps = (self.n_layers + len(p) - 1) // len(p)
        return (p * reps)[: self.n_layers]

    def reduced(self, *, n_layers: int = 2, d_model: int = 64,
                n_heads: int = 4, n_kv: int | None = None, d_ff: int | None = None,
                vocab: int = 256, experts: int = 4) -> "ModelConfig":
        """Smoke-test shrink of the same family (same block kinds/pattern)."""
        kw: dict = {}
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(self.moe, num_experts=experts,
                                            top_k=min(self.moe.top_k, 2),
                                            impl="einsum")
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=8, qk_rope_head_dim=8,
                                  v_head_dim=8)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8, chunk=16)
        dff = d_ff if d_ff is not None else (0 if self.d_ff == 0 else 128)
        pattern = self.block_pattern
        if len(pattern) > n_layers or n_layers % len(pattern):
            uniq = tuple(dict.fromkeys(pattern))  # keep kind diversity
            assert n_layers % len(uniq) == 0, (self.name, n_layers, uniq)
            kw["block_pattern"] = uniq
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv=n_kv if n_kv is not None else min(self.n_kv, n_heads),
            d_ff=dff, vocab=vocab, head_dim=d_model // n_heads,
            window=min(self.window, 32) if self.window else None,
            param_dtype="float32", compute_dtype="float32", **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
