"""musicgen-medium — 48L d1536 24H(kv24) d_ff6144 vocab2048, decoder-only over
EnCodec tokens (frontend stubbed: input_specs provides frame embeddings)
[arXiv:2306.05284; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv=24, d_ff=6144, vocab=2048, embed_stub=True,
)
