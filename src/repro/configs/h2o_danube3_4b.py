"""h2o-danube-3-4b — 24L d3840 32H(kv8) d_ff10240 vocab32000, llama+mistral
mix with sliding-window attention [arXiv:2401.16818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o_danube3_4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv=8, d_ff=10240, vocab=32000, attn="swa", window=4096,
    subquadratic=True,  # SWA bounds KV — long_500k runnable
)
