"""Per-layer cost profiles — the paper's (m_j, c_j, K_j) triples.

The paper characterizes each CNN layer j by a memory requirement ``m_j``,
a computation demand ``c_j`` and the size ``K_j`` of the activation it ships
to the next layer (§III-A).  We generalize that to any layered model:
LeNet / VGG-16 (the paper's own workloads) and the transformer-family
architectures this framework supports.  Profiles are analytic — derived from
the layer hyper-parameters, never from tracing — so they are cheap enough to
recompute inside the placement loop (OULD re-solve on topology change).

Units: memory in bytes, compute in FLOPs, activation sizes in bytes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """The paper's (m_j, c_j, K_j) for one placement unit."""

    name: str
    memory_bytes: float        # m_j: params + working activations resident on the node
    compute_flops: float       # c_j: FLOPs to execute the layer once
    output_bytes: float        # K_j: activation shipped to layer j+1
    params_bytes: float = 0.0  # informational split of memory_bytes


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Ordered layer profiles for one model + the input size K_s."""

    name: str
    layers: tuple[LayerProfile, ...]
    input_bytes: float  # K_s: the source image / token batch shipped to layer 1

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def total_memory(self) -> float:
        return sum(ly.memory_bytes for ly in self.layers)

    @property
    def total_flops(self) -> float:
        return sum(ly.compute_flops for ly in self.layers)

    def memory_vector(self) -> list[float]:
        return [ly.memory_bytes for ly in self.layers]

    def compute_vector(self) -> list[float]:
        return [ly.compute_flops for ly in self.layers]

    def output_vector(self) -> list[float]:
        """K_j for j = 1..M (K_M is the classification result, tiny)."""
        return [ly.output_bytes for ly in self.layers]


# ---------------------------------------------------------------------------
# CNN profiles (paper workloads): LeNet (7 sub-tasks) and VGG-16 (18 sub-tasks)
# ---------------------------------------------------------------------------

def _conv2d_profile(name: str, h: int, w: int, cin: int, cout: int, k: int,
                    stride: int = 1, pad: str = "same",
                    dtype_bytes: int = 4) -> tuple[LayerProfile, int, int]:
    if pad == "same":
        ho, wo = math.ceil(h / stride), math.ceil(w / stride)
    else:  # valid
        ho, wo = (h - k) // stride + 1, (w - k) // stride + 1
    params = (k * k * cin + 1) * cout
    flops = 2.0 * k * k * cin * cout * ho * wo
    out_bytes = ho * wo * cout * dtype_bytes
    mem = params * dtype_bytes + out_bytes + h * w * cin * dtype_bytes
    return (LayerProfile(name, mem, flops, out_bytes, params * dtype_bytes), ho, wo)


def _pool_profile(name: str, h: int, w: int, c: int, k: int,
                  dtype_bytes: int = 4) -> tuple[LayerProfile, int, int]:
    ho, wo = h // k, w // k
    flops = 1.0 * k * k * c * ho * wo
    out_bytes = ho * wo * c * dtype_bytes
    mem = out_bytes + h * w * c * dtype_bytes
    return (LayerProfile(name, mem, flops, out_bytes, 0.0), ho, wo)


def _dense_profile(name: str, fan_in: int, fan_out: int,
                   dtype_bytes: int = 4) -> LayerProfile:
    params = (fan_in + 1) * fan_out
    flops = 2.0 * fan_in * fan_out
    out_bytes = fan_out * dtype_bytes
    mem = params * dtype_bytes + out_bytes + fan_in * dtype_bytes
    return LayerProfile(name, mem, flops, out_bytes, params * dtype_bytes)


def lenet_profile(height: int = 326, width: int = 595, channels: int = 3,
                  dtype_bytes: int = 4) -> ModelProfile:
    """LeNet-5 style, 7 placement units (paper: 'Lenet composed of 7 layers').

    The paper classifies 595x326 RGB frames from the Stanford Drone Dataset;
    we keep the classic LeNet filter counts but honor the paper's input size.
    """
    layers: list[LayerProfile] = []
    h, w = height, width
    p, h, w = _conv2d_profile("conv1", h, w, channels, 6, 5, pad="valid",
                              dtype_bytes=dtype_bytes)
    layers.append(p)
    p, h, w = _pool_profile("pool1", h, w, 6, 2, dtype_bytes)
    layers.append(p)
    p, h, w = _conv2d_profile("conv2", h, w, 6, 16, 5, pad="valid",
                              dtype_bytes=dtype_bytes)
    layers.append(p)
    p, h, w = _pool_profile("pool2", h, w, 16, 2, dtype_bytes)
    layers.append(p)
    flat = h * w * 16
    layers.append(_dense_profile("fc1", flat, 120, dtype_bytes))
    layers.append(_dense_profile("fc2", 120, 84, dtype_bytes))
    layers.append(_dense_profile("fc3", 84, 10, dtype_bytes))
    input_bytes = height * width * channels * 1.0  # uint8 capture, K_s
    return ModelProfile("lenet", tuple(layers), input_bytes)


_VGG16_CFG: Sequence[tuple[str, int]] = (
    ("conv", 64), ("conv", 64), ("pool", 0),
    ("conv", 128), ("conv", 128), ("pool", 0),
    ("conv", 256), ("conv", 256), ("conv", 256), ("pool", 0),
    ("conv", 512), ("conv", 512), ("conv", 512), ("pool", 0),
    ("conv", 512), ("conv", 512), ("conv", 512), ("pool", 0),
)


def vgg16_profile(height: int = 326, width: int = 595, channels: int = 3,
                  dtype_bytes: int = 4, num_classes: int = 10,
                  merge_to: int = 18) -> ModelProfile:
    """VGG-16 as 18 placement units (paper: 'VGG-16 that comprises 18 layers').

    13 conv + 5 pool = 18 feature units; the 3 FC layers are folded into the
    last pool unit so the unit count matches the paper's M=18.  (The paper
    counts 'sub-tasks', not keras layers; 18 is their number.)
    """
    layers: list[LayerProfile] = []
    h, w, c = height, width, channels
    for kind, cout in _VGG16_CFG:
        if kind == "conv":
            p, h, w = _conv2d_profile(f"conv{len(layers)}", h, w, c, cout, 3,
                                      dtype_bytes=dtype_bytes)
            c = cout
        else:
            p, h, w = _pool_profile(f"pool{len(layers)}", h, w, c, 2, dtype_bytes)
        layers.append(p)
    # Fold classifier head into the final unit (adaptive-pool 7x7 + 3 FC).
    head_in = 7 * 7 * 512
    head = [
        _dense_profile("fc6", head_in, 4096, dtype_bytes),
        _dense_profile("fc7", 4096, 4096, dtype_bytes),
        _dense_profile("fc8", 4096, num_classes, dtype_bytes),
    ]
    last = layers[-1]
    layers[-1] = LayerProfile(
        name=last.name + "+head",
        memory_bytes=last.memory_bytes + sum(x.memory_bytes for x in head),
        compute_flops=last.compute_flops + sum(x.compute_flops for x in head),
        output_bytes=head[-1].output_bytes,
        params_bytes=last.params_bytes + sum(x.params_bytes for x in head),
    )
    assert len(layers) == merge_to, len(layers)
    input_bytes = height * width * channels * 1.0
    return ModelProfile("vgg16", tuple(layers), input_bytes)


# ---------------------------------------------------------------------------
# Transformer profiles — placement units are decoder blocks (+ embed / head)
# ---------------------------------------------------------------------------

def transformer_block_flops(d_model: int, n_heads: int, n_kv: int, d_ff: int,
                            seq: int, *, head_dim: int | None = None,
                            moe_experts: int = 0, moe_topk: int = 0,
                            window: int | None = None,
                            causal: bool = True) -> float:
    """Analytic per-token-batch FLOPs of one decoder block over ``seq`` tokens."""
    hd = head_dim if head_dim is not None else d_model // max(n_heads, 1)
    qkv = 2.0 * seq * d_model * (n_heads + 2 * n_kv) * hd
    proj = 2.0 * seq * n_heads * hd * d_model
    ctx = min(seq, window) if window else seq
    attn_scores = 2.0 * seq * ctx * n_heads * hd * (0.5 if causal and not window else 1.0)
    attn = 2 * attn_scores  # scores + weighted sum
    if moe_experts and moe_topk:
        ffn = 2.0 * seq * d_model * d_ff * 3 * moe_topk  # gate/up/down per routed expert
        router = 2.0 * seq * d_model * moe_experts
        ffn += router
    elif d_ff > 0:
        ffn = 2.0 * seq * d_model * d_ff * 3
    else:
        ffn = 0.0
    return qkv + proj + attn + ffn


def transformer_block_params(d_model: int, n_heads: int, n_kv: int, d_ff: int,
                             *, head_dim: int | None = None,
                             moe_experts: int = 0) -> float:
    hd = head_dim if head_dim is not None else d_model // max(n_heads, 1)
    attn = d_model * (n_heads + 2 * n_kv) * hd + n_heads * hd * d_model
    if moe_experts:
        ffn = moe_experts * 3.0 * d_model * d_ff + d_model * moe_experts
    elif d_ff > 0:
        ffn = 3.0 * d_model * d_ff
    else:
        ffn = 0.0
    norms = 2.0 * d_model
    return attn + ffn + norms


def lm_profile(name: str, *, n_layers: int, d_model: int, n_heads: int,
               n_kv: int, d_ff: int, vocab: int, seq: int, batch: int = 1,
               head_dim: int | None = None, moe_experts: int = 0,
               moe_topk: int = 0, window: int | None = None,
               dtype_bytes: int = 2) -> ModelProfile:
    """Per-block (m_j, c_j, K_j) for a decoder LM — placement units are blocks,
    with embedding and LM head as the first/last units (the paper's layer-wise
    granularity, adapted per DESIGN.md §2)."""
    act = batch * seq * d_model * dtype_bytes * 1.0
    layers: list[LayerProfile] = [
        LayerProfile("embed", vocab * d_model * dtype_bytes + act,
                     2.0 * batch * seq * d_model, act,
                     vocab * d_model * dtype_bytes),
    ]
    blk_p = transformer_block_params(d_model, n_heads, n_kv, d_ff,
                                     head_dim=head_dim, moe_experts=moe_experts)
    blk_f = batch * transformer_block_flops(d_model, n_heads, n_kv, d_ff, seq,
                                            head_dim=head_dim,
                                            moe_experts=moe_experts,
                                            moe_topk=moe_topk, window=window)
    hd = head_dim if head_dim is not None else d_model // max(n_heads, 1)
    kv_bytes = batch * min(seq, window or seq) * 2 * n_kv * hd * dtype_bytes
    for j in range(n_layers):
        layers.append(LayerProfile(
            f"block{j}", blk_p * dtype_bytes + act + kv_bytes, blk_f, act,
            blk_p * dtype_bytes))
    head_flops = 2.0 * batch * seq * d_model * vocab
    layers.append(LayerProfile(
        "lm_head", vocab * d_model * dtype_bytes + batch * seq * vocab * dtype_bytes,
        head_flops, batch * seq * 4.0,  # K_M: the decision (token ids), tiny
        vocab * d_model * dtype_bytes))
    return ModelProfile(name, tuple(layers), input_bytes=batch * seq * 4.0)
