"""Batched jitted min-plus DP kernel: one dispatch per epoch, not per request.

The sparse k-candidate DP (:mod:`repro.core.ould`) solves each request as an
``(M-1, k, k)`` min-plus sweep over pre-selected candidate nodes.  The sweep
is already array-shaped, but the sequential solver runs it request-at-a-time
in Python — at N = 1024 the per-request interpreter overhead (candidate
selection, the M-step Python loop over tiny k×k numpy ops) dominates the
epoch re-solve.  This module moves the sweep into a single jitted JAX kernel
that solves a whole *batch of rows* (one row per distinct request source) in
one dispatch:

* rows are stacked ``(S, M, k)`` candidate/validity arrays from
  :func:`~repro.core.ould._sparse_select`;
* the layer sweep runs the M-1 transitions as a statically unrolled loop of
  batched k×k min-plus blocks, with the transition tensor *gathered inside
  the kernel* (the ``spb`` matrix is pushed to the device once per topology
  and cached) and the infeasibility penalty / per-layer compute cost folded
  in exactly as the sequential kernel folds them;
* argmin backtracking recovers per-row placements (vectorized over rows on
  the host — it is O(S·M) index chasing, not worth a kernel).

Bit-identity contract
---------------------
The batched kernel must reproduce :func:`~repro.core.ould._sparse_run`
bit-for-bit — the admission decision of the greedy solve hangs on float
comparisons against the ``max_path_cost`` bar and the ``_BIG`` sentinel.
Three properties guarantee it:

1. all arithmetic runs in float64 (``jax.experimental.enable_x64`` around
   trace and dispatch — the rest of the repo stays on default f32), with the
   same per-element operation order as the numpy reference (gather-multiply,
   then + penalty, then + compute, then + carried cost);
2. ``jnp.argmin`` and ``np.argmin`` both return the *first* minimum, so
   tie-breaking over the ascending-node-ordered candidate axis matches; the
   carried cost uses ``jnp.min``, whose value equals the element at the
   argmin (no NaNs can occur — costs are products and sums of non-negative
   finite rates plus {0, inf} penalties);
3. the element gathered for a transition is the identical ``spb`` float the
   numpy kernel reads.

Padding / bucketing contract
----------------------------
XLA compiles one executable per input shape.  The row count S varies every
epoch (it tracks the live request set), so rows are padded up to the next
power-of-two bucket (floor :data:`MIN_BUCKET`) before dispatch and sliced
back after: re-solving with a different S only recompiles when S crosses a
bucket boundary.  (M is pinned by the model profile and k by the ladder
level, so those axes are naturally stable.)  Padded rows carry benign zeros
and are never read back.  :func:`compile_count` exposes the jit cache size
so tests can pin the contract.
"""

from __future__ import annotations

import numpy as np

MIN_BUCKET = 8

_kernel = None      # lazily built jitted sweep (keeps jax off the cold path)
_spb_cache: tuple | None = None   # (numpy spb, device spb) — `is`-keyed


def bucket_rows(n_rows: int) -> int:
    """Pad ``n_rows`` up to the next power-of-two bucket (≥ MIN_BUCKET)."""
    b = MIN_BUCKET
    while b < n_rows:
        b *= 2
    return b


def _build_kernel():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def sweep(spb, Kv, Ks, srcs, cand, pen, cc):
        """spb (N,N); srcs (S,); cand/pen (S,M,k); cc (M,N) or None
        → final (S,k) min-plus costs, backs (M-1,S,k) argmin back-pointers."""
        N = spb.shape[0]
        flat = spb.ravel()                         # flat take beats 2D gather
        c = Ks * jnp.take(flat, srcs[:, None] * N + cand[:, 0, :]) + pen[:, 0]
        if cc is not None:
            c = c + cc[0, cand[:, 0, :]]
        M = cand.shape[1]
        backs = []
        for j in range(1, M):                      # static unroll over layers
            tr = Kv[j - 1] * jnp.take(flat, cand[:, j - 1, :, None] * N
                                      + cand[:, j, None, :])
            tr = tr + pen[:, j, None, :]
            if cc is not None:
                tr = tr + cc[j, cand[:, j, :]][:, None, :]
            step = c[:, :, None] + tr              # (S, k_prev, k_cur)
            backs.append(jnp.argmin(step, axis=1))  # first min — numpy parity
            c = jnp.min(step, axis=1)
        if backs:
            return c, jnp.stack(backs)
        return c, jnp.zeros((0,) + c.shape, jnp.int64)

    return sweep


def _get_kernel():
    global _kernel
    if _kernel is None:
        _kernel = _build_kernel()
    return _kernel


def compile_count() -> int:
    """Number of distinct shapes the sweep kernel has compiled for (tests pin
    the padding contract: same bucket ⇒ no recompilation)."""
    if _kernel is None:
        return 0
    return int(_kernel._cache_size())


def _device_spb(spb: np.ndarray):
    """Push the seconds-per-bit matrix to the device once per topology.

    Keyed by object identity; holding the numpy reference keeps the id alive,
    so a stale hit is impossible.  One slot suffices — a solve works one
    topology at a time.
    """
    global _spb_cache
    import jax.numpy as jnp

    if _spb_cache is None or _spb_cache[0] is not spb:
        _spb_cache = (spb, jnp.asarray(spb))
    return _spb_cache[1]


def solve_batch(spb: np.ndarray, Ks: float, compute_cost: np.ndarray | None,
                srcs: np.ndarray, cand: np.ndarray, valid: np.ndarray,
                consts: tuple) -> tuple[list[np.ndarray | None], np.ndarray]:
    """Solve a batch of pruned DPs in one kernel dispatch.

    ``srcs`` (S,) request sources; ``cand``/``valid`` (S, M, k) stacked
    per-row candidate selections (:func:`~repro.core.ould._sparse_select`).
    Returns ``(paths, costs)`` — per row the argmin-backtracked placement
    (None when no finite path survives the feasibility penalty) and its
    cost, bit-identical to running :func:`~repro.core.ould._sparse_run` on
    each row sequentially.
    """
    from jax.experimental import enable_x64

    Kv = np.asarray(consts[0], np.float64)
    S, M, kk = cand.shape
    pen = np.where(valid, 0.0, np.inf)                        # (S, M, kk)
    Sp = bucket_rows(S)
    if Sp != S:
        srcs = np.concatenate([srcs, np.zeros(Sp - S, srcs.dtype)])
        cand = np.concatenate([cand, np.zeros((Sp - S, M, kk), cand.dtype)])
        pen = np.concatenate([pen, np.zeros((Sp - S, M, kk))])
    with enable_x64():
        f, b = _get_kernel()(_device_spb(spb), Kv, np.float64(Ks),
                             srcs, cand, pen, compute_cost)
        final = np.asarray(f)[:S]
        backs = np.asarray(b)[:, :S]
    # Vectorized backtrack — mirrors _sparse_run's per-row argmin walk.
    rows = np.arange(S)
    ends = np.argmin(final, axis=1)
    finite = np.isfinite(final[rows, ends])
    nodes = np.empty((S, M), np.int64)
    idx = ends.copy()
    nodes[:, M - 1] = cand[rows, M - 1, idx]
    for j in range(M - 1, 0, -1):
        idx = backs[j - 1, rows, idx]
        nodes[:, j - 1] = cand[rows, j - 1, idx]
    paths: list[np.ndarray | None] = [
        nodes[q] if finite[q] else None for q in range(S)]
    costs = np.where(finite, final[rows, ends], np.inf)
    return paths, costs
