"""The paper's primary contribution: latency-optimal layer placement
(OULD / OULD-MP) over heterogeneous-bandwidth node topologies, plus the
system-model substrates (per-layer profiles, radio link model, RPG
mobility) and the heuristic baselines it is evaluated against.
"""

from .events import ChurnEvent, Event, EventKind, EventQueue, churn_events, poisson_process
from .heuristics import solve_heuristic
from .latency import Evaluation, evaluate
from .mobility import MultiGroupMobility, RPGMobility, RPGParams
from .ould import (IncrementalSolver, Problem, ResolveStats, Solution,
                   solve_ould)
from .ould_mp import (MPResult, solve_offline_fixed, solve_ould_mp,
                      solve_static_resolve)
from .placement import (Stage, balanced_stages, ould_pipeline_stages,
                        stage_boundaries, to_stages)
from .profiles import (LayerProfile, ModelProfile, lenet_profile, lm_profile,
                       vgg16_profile)
from .radio import RadioParams, TpuLinkModel, rate_matrix, sinr_matrix

__all__ = [
    "ChurnEvent", "Evaluation", "Event", "EventKind", "EventQueue",
    "IncrementalSolver", "LayerProfile", "MPResult", "ModelProfile",
    "MultiGroupMobility", "Problem", "RPGMobility", "RPGParams",
    "RadioParams", "ResolveStats", "Solution", "Stage", "TpuLinkModel",
    "balanced_stages", "churn_events", "evaluate", "lenet_profile",
    "lm_profile", "ould_pipeline_stages", "poisson_process", "rate_matrix",
    "sinr_matrix", "solve_heuristic", "solve_offline_fixed", "solve_ould",
    "solve_ould_mp", "solve_static_resolve", "stage_boundaries", "to_stages",
    "vgg16_profile",
]
