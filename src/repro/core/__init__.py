"""The paper's primary contribution: latency-optimal layer placement
(OULD / OULD-MP) over heterogeneous-bandwidth node topologies, plus the
system-model substrates (per-layer profiles, radio link model, RPG
mobility) and the heuristic baselines it is evaluated against.
"""

from .events import ChurnEvent, Event, EventKind, EventQueue, churn_events, poisson_process
from .heuristics import solve_heuristic
from .latency import Evaluation, evaluate
from .mobility import MultiGroupMobility, RPGMobility, RPGParams
from .ould import (IncrementalSolver, Problem, ResolveStats, Solution,
                   default_sparse_k, improvement_bound,
                   incremental_transfer_cost, placement_drift, solve_ould,
                   transfer_cost)
from .ould_mp import (MPResult, solve_offline_fixed, solve_ould_mp,
                      solve_static_resolve)
from .placement import (Stage, balanced_stages, ould_pipeline_stages,
                        stage_boundaries, to_stages)
from .planner import (HorizonView, IncrementalPlanner, NoisyHorizonView,
                      Plan, Planner, SnapshotView, StaleView, TopologyView,
                      available_planners, get_planner, make_view,
                      register_planner)
from .profiles import (LayerProfile, ModelProfile, lenet_profile, lm_profile,
                       vgg16_profile)
from .radio import RadioParams, TpuLinkModel, rate_matrix, sinr_matrix

__all__ = [
    "ChurnEvent", "Evaluation", "Event", "EventKind", "EventQueue",
    "HorizonView", "IncrementalPlanner", "IncrementalSolver", "LayerProfile",
    "MPResult", "ModelProfile", "MultiGroupMobility", "NoisyHorizonView",
    "Plan", "Planner",
    "Problem", "RPGMobility", "RPGParams", "RadioParams", "ResolveStats",
    "SnapshotView", "Solution", "Stage", "StaleView", "TopologyView",
    "TpuLinkModel",
    "available_planners", "balanced_stages", "churn_events",
    "default_sparse_k", "evaluate",
    "get_planner", "improvement_bound", "incremental_transfer_cost",
    "lenet_profile",
    "lm_profile", "make_view", "ould_pipeline_stages", "placement_drift",
    "poisson_process",
    "rate_matrix", "register_planner", "sinr_matrix", "solve_heuristic",
    "solve_offline_fixed", "solve_ould", "solve_ould_mp",
    "solve_static_resolve", "stage_boundaries", "to_stages", "transfer_cost",
    "vgg16_profile",
]
