"""The paper's primary contribution: latency-optimal layer placement
(OULD / OULD-MP) over heterogeneous-bandwidth node topologies, plus the
system-model substrates (per-layer profiles, radio link model, RPG
mobility) and the heuristic baselines it is evaluated against.
"""

from .heuristics import solve_heuristic
from .latency import Evaluation, evaluate
from .mobility import RPGMobility, RPGParams
from .ould import Problem, Solution, solve_ould
from .ould_mp import (MPResult, solve_offline_fixed, solve_ould_mp,
                      solve_static_resolve)
from .placement import (Stage, balanced_stages, ould_pipeline_stages,
                        stage_boundaries, to_stages)
from .profiles import (LayerProfile, ModelProfile, lenet_profile, lm_profile,
                       vgg16_profile)
from .radio import RadioParams, TpuLinkModel, rate_matrix, sinr_matrix

__all__ = [
    "Evaluation", "LayerProfile", "MPResult", "ModelProfile", "Problem",
    "RPGMobility", "RPGParams", "RadioParams", "Solution", "Stage",
    "TpuLinkModel", "balanced_stages", "evaluate", "lenet_profile",
    "lm_profile", "ould_pipeline_stages", "rate_matrix", "sinr_matrix",
    "solve_heuristic", "solve_offline_fixed", "solve_ould", "solve_ould_mp",
    "solve_static_resolve", "stage_boundaries", "to_stages", "vgg16_profile",
]
