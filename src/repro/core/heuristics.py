"""Heuristic baselines from the paper (§IV-A, Fig. 8).

* **Nearest** — the node currently holding the computation greedily executes
  layers until its residual memory/compute cannot fit the next layer, then
  hands the intermediate output to the *nearest* neighbor with enough
  residual memory for at least the next layer.
* **HRM** (High Residual Memory) — hand off to the neighbor with the highest
  residual memory.
* **Nearest-HRM** — among the nearest feasible neighbors (closest tercile),
  pick the one with the highest residual memory.

All three are single-configuration heuristics ("designed for a single network
configuration obtained from a fixed time step"), so they consume a (N, N)
rate snapshot, never the MP horizon.
"""

from __future__ import annotations

import time
from typing import Callable, Literal

import numpy as np

from .ould import Problem, Solution

Heuristic = Literal["nearest", "hrm", "nearest_hrm"]


def _pick_nearest(cands: np.ndarray, dist: np.ndarray, mem_left: np.ndarray) -> int:
    return int(cands[np.argmin(dist[cands])])


def _pick_hrm(cands: np.ndarray, dist: np.ndarray, mem_left: np.ndarray) -> int:
    return int(cands[np.argmax(mem_left[cands])])


def _pick_nearest_hrm(cands: np.ndarray, dist: np.ndarray,
                      mem_left: np.ndarray) -> int:
    order = cands[np.argsort(dist[cands])]
    near = order[: max(1, int(np.ceil(len(order) / 3)))]
    return int(near[np.argmax(mem_left[near])])


_PICKERS: dict[str, Callable[..., int]] = {
    "nearest": _pick_nearest,
    "hrm": _pick_hrm,
    "nearest_hrm": _pick_nearest_hrm,
}


def solve_heuristic(prob: Problem, kind: Heuristic) -> Solution:
    """Greedy hand-off placement (legacy entry point — new code uses
    ``get_planner("nearest" | "hrm" | "nearest-hrm")``).

    'Distance' is derived from the rate matrix
    (higher rate ⇔ nearer — §III-C: 'lower data rates correspond to distant
    UAVs and vice-versa'), so the heuristics see exactly the information a
    real swarm would estimate from its links."""
    t0 = time.perf_counter()
    rates = prob.rates if prob.rates.ndim == 2 else prob.rates[0]
    with np.errstate(divide="ignore"):
        dist = np.where(rates > 0, 1.0 / np.maximum(rates, 1e-30), np.inf)
    np.fill_diagonal(dist, 0.0)
    spb = prob.transfer_cost()

    N, M, R = prob.n_nodes, prob.n_layers, prob.n_requests
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()
    K = prob.profile.output_vector()
    mem_left = prob.mem_cap.astype(float).copy()
    comp_left = prob.comp_cap.astype(float).copy()
    pick = _PICKERS[kind]

    # Rejected rows keep the -1 sentinel: a rejected request must never be
    # mistaken for "all layers on node 0" (evaluate() enforces this).
    assign = np.full((R, M), -1, np.int64)
    admitted = np.ones(R, bool)
    total = 0.0
    for r in range(R):
        cur = int(prob.sources[r])
        placed: list[int] = []
        lat = 0.0
        ok = True
        for j in range(M):
            if mem_left[cur] >= mem[j] and comp_left[cur] >= comp[j]:
                nxt = cur
            else:
                cands = np.array([
                    k for k in range(N)
                    if k != cur and np.isfinite(dist[cur, k])
                    and mem_left[k] >= mem[j] and comp_left[k] >= comp[j]
                ])
                if cands.size == 0:
                    ok = False
                    break
                nxt = pick(cands, dist[cur], mem_left)
                lat += (prob.profile.input_bytes if j == 0 else K[j - 1]) * spb[cur, nxt]
            mem_left[nxt] -= mem[j]
            comp_left[nxt] -= comp[j]
            placed.append(nxt)
            cur = nxt
        if not ok:
            admitted[r] = False
            # roll back partial reservations
            for j, i in enumerate(placed):
                mem_left[i] += mem[j]
                comp_left[i] += comp[j]
            continue
        assign[r] = placed
        total += lat
    status = "feasible" if admitted.all() else f"rejected:{int((~admitted).sum())}"
    return Solution(assign, total, status, time.perf_counter() - t0, admitted,
                    solver=kind)
