"""Placement evaluation — the quantities the paper plots.

Given a placement (R, M) → node, reproduce the paper's two headline metrics
(§IV): *average end-to-end latency per request* split into communication and
computation components (Fig. 4a/5/6 solid vs dashed lines), and *shared data*
— total bytes exchanged between participants (Fig. 4b/7).  Also validates
capacity feasibility (Eq. 4/5), which the tests use as an invariant.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .ould import Problem, Solution


@dataclasses.dataclass(frozen=True)
class Evaluation:
    comm_latency_s: float        # paper objective value (per horizon)
    comp_latency_s: float        # Σ c_j / speed_i over placed layers
    shared_bytes: float          # total inter-node traffic (incl. source img)
    per_request_s: np.ndarray    # (R,) end-to-end latency per admitted request
    feasible: bool
    n_admitted: int

    @property
    def total_latency_s(self) -> float:
        return self.comm_latency_s + self.comp_latency_s

    @property
    def avg_latency_per_request(self) -> float:
        if self.n_admitted == 0:
            return float("inf")
        return float(self.per_request_s[np.isfinite(self.per_request_s)].sum()
                     / self.n_admitted)


def evaluate(prob: Problem, sol: Solution) -> Evaluation:
    spb = prob.transfer_cost()
    K = prob.profile.output_vector()
    Ks = prob.profile.input_bytes
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()
    R, M, N = prob.n_requests, prob.n_layers, prob.n_nodes

    speed = prob.compute_speed
    if speed is None:
        speed = np.full(N, np.inf)

    mem_use = np.zeros(N)
    comp_use = np.zeros(N)
    comm_total = 0.0
    comp_total = 0.0
    shared = 0.0
    per_req = np.full(R, np.inf)
    for r in range(R):
        if not sol.admitted[r]:
            # Non-admitted rows are never read: they carry the -1 sentinel.
            continue
        path = sol.assign[r]
        assert (path >= 0).all() and (path < N).all(), \
            f"request {r} marked admitted but its row holds the rejection " \
            f"sentinel / an invalid node id: {path}"
        src = int(prob.sources[r])
        comm = 0.0
        cmp_ = 0.0
        if path[0] != src:
            comm += Ks * spb[src, path[0]]
            shared += Ks
        for j in range(M):
            i = int(path[j])
            mem_use[i] += mem[j]
            comp_use[i] += comp[j]
            cmp_ += comp[j] / speed[i] * prob.horizon()
            if j < M - 1 and path[j + 1] != i:
                comm += K[j] * spb[i, int(path[j + 1])]
                shared += K[j]
        per_req[r] = comm + cmp_
        comm_total += comm
        comp_total += cmp_
    feasible = bool(np.all(mem_use <= prob.mem_cap + 1e-6)
                    and np.all(comp_use <= prob.comp_cap + 1e-6))
    return Evaluation(comm_total, comp_total, shared, per_req, feasible,
                      int(sol.admitted.sum()))
