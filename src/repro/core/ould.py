"""OULD — Optimal UAV-based Layer Distribution (paper §III-B, Eq. 3–13)
and OULD-MP — with mobility prediction (paper §III-C, Eq. 14–15).

Decision variables
------------------
``α_{r,i,j} ∈ {0,1}``  — node i executes layer j of request r (Eq. 2).
``γ_{r,i,k,j} ∈ {0,1}`` — node i runs layer j of r AND node k runs layer j+1
(Eq. 9/10), introduced to linearize the bilinear objective via the big-M
rules (Eq. 11):

    γ ≤ α_{r,i,j},   γ ≤ α_{r,k,j+1},   γ ≥ α_{r,i,j} + α_{r,k,j+1} − 1.

Objective (Eq. 12 + 13):  min Σ_r Σ_{i≠k} Σ_{j<M} γ_{r,i,k,j}·K_j/ρ_{i,k} + t_s
with t_s the source-image transfer.  Because Σ_i α_{r,i,1} = 1 (Eq. 6), the
source term is *already linear*: t_s = Σ_{k≠src(r)} α_{r,k,1}·K_s/ρ_{src,k}.

Constraints: per-node memory (Eq. 4) and compute (Eq. 5) occupancy caps, and
exactly-one placement per (request, layer) (Eq. 6); binariness (Eq. 7).

Solvers
-------
* ``solver="ilp"``   — paper-faithful ILP via HiGHS (`scipy.optimize.milp`).
  ``gamma_relaxed=True`` (default) declares γ continuous in [0,1]: with the
  big-M inequalities and binary α, γ* = α_i·α_k at every vertex, so the optimum
  is unchanged while the branch-and-bound tree only explores α.  This is an
  exactness-preserving speedup (validated against the all-binary mode in
  tests).  ``tight=True`` keeps the two ≤ inequalities the paper writes; they
  are redundant for a non-negative objective but retained by default for
  faithfulness.
* ``solver="dp"``    — exact per-request shortest-path DP through the N×M
  lattice when capacity constraints are slack; with contention it becomes a
  sequential greedy-DP (requests placed one at a time, capacities decremented)
  — our large-instance fallback, also the warm-start generator.
* ``solver="dp-sparse"`` — the same sequential greedy-DP with each layer's
  transition pruned to the ``k`` best candidate nodes (ranked by residual
  seconds/byte from the request's source plus a capacity-headroom tiebreak)
  instead of scanning all N×N transitions: O(M·(N + k²)) per request instead
  of O(M·N²).  A fallback ladder keeps each request's admission decision
  identical to ``"dp"``'s *under the same residual capacities*: whenever the
  pruned DP rejects (or only finds a path over a ``_BIG``-priced link), the
  request retries with k doubled and, as a last resort, the dense kernel —
  and at k ≥ N the two solvers are bit-identical by construction.  At k < N
  an admitted request's *path* may differ, so residuals can diverge across
  the greedy sequence and whole-solve admission equality is an empirical,
  seed-pinned property (checked by bench_swarm S5 and the equivalence
  tests), not a structural guarantee.
  This is the ROADMAP's N ≥ 50 swarm regime (bench_swarm S5).
  With ``batch_solve=True`` the per-request (M-1, k, k) min-plus sweeps of
  one solve are precomputed in a single jitted JAX dispatch
  (:mod:`repro.core.batch_dp`) and consumed greedily under a certification
  rule that keeps every admission decision — and every admitted path —
  bit-identical to the sequential sparse solve; requests the batched pass
  cannot certify or admit fall back to the sequential ladder (bench_swarm
  S7 locks the N = 1024 epoch re-solve speedup).

OULD-MP is the same formulation with rate coefficients summed over the
predicted horizon: cost(i,k) uses Σ_t 1/ρ_{i,k}(t) (Eq. 14).  A pair that is
predicted to *disconnect* (ρ=0 at any t) gets an infinite coefficient, which
is exactly the paper's argument for why MP avoids mid-mission outages.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Literal

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from .profiles import ModelProfile

Solver = Literal["ilp", "dp", "dp-sparse"]

_BIG = 1e12  # stand-in for an unreachable (disconnected) pair


@dataclasses.dataclass(frozen=True)
class Problem:
    """One OULD instance (a set of concurrent requests on a topology)."""

    profile: ModelProfile
    mem_cap: np.ndarray          # (N,) m̄_i, bytes
    comp_cap: np.ndarray         # (N,) c̄_i, FLOPs budget per decision period
    rates: np.ndarray            # (N,N) ρ bits/s — or (T,N,N) for OULD-MP
    sources: np.ndarray          # (R,) source node of each request (μ_{i,r})
    compute_speed: np.ndarray | None = None  # (N,) FLOPs/s for latency eval
    rate_unit_bytes: float = 1 / 8.0  # bits/s rates → bytes = K·8/ρ
    # Provenance of the rates: "analytic" (radio model) or
    # "measured:<transport>" when a byte-moving backend calibrated them
    # (repro.exec.calibrate.calibrate_rates) — rides into Plan.problem.
    comm_source: str = "analytic"

    @property
    def n_nodes(self) -> int:
        return int(self.mem_cap.shape[0])

    @property
    def n_requests(self) -> int:
        return int(self.sources.shape[0])

    @property
    def n_layers(self) -> int:
        return self.profile.num_layers

    def horizon(self) -> int:
        return 1 if self.rates.ndim == 2 else int(self.rates.shape[0])

    def transfer_cost(self) -> np.ndarray:
        """(N,N) seconds per byte between node pairs, summed over the horizon
        (Eq. 14 sums transfer latency over t ∈ {1..T})."""
        return transfer_cost(self.rates, self.rate_unit_bytes)


def transfer_cost(rates: np.ndarray,
                  rate_unit_bytes: float = 1 / 8.0) -> np.ndarray:
    """Full (N,N) seconds/byte pricing of a rate matrix or horizon stack."""
    r3 = rates[None] if rates.ndim == 2 else rates
    secs_per_byte = np.zeros(r3.shape[1:])
    for t in range(r3.shape[0]):
        r = r3[t]
        with np.errstate(divide="ignore"):
            spb = np.where(r > 0, (1.0 / rate_unit_bytes) / np.maximum(r, 1e-30), _BIG)
        np.fill_diagonal(spb, 0.0)  # same node: no transfer
        secs_per_byte = secs_per_byte + spb
    return secs_per_byte


def incremental_transfer_cost(
        rates: np.ndarray, ref_rates: np.ndarray, ref_spb: np.ndarray, *,
        rel_change: float = 0.0, rate_unit_bytes: float = 1 / 8.0,
        repriced: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Re-price only entries of the seconds/byte matrix whose rates moved
    (beyond ``rel_change`` relative drift; 0.0 ⇒ exact: any change at all).

    ``ref_rates``/``ref_spb`` are the rates each entry was last priced at
    and the matching cost matrix.  Returns ``(spb, repriced)`` with
    ``repriced`` the (N, N) bool mask of re-priced pairs; entries outside it
    are carried over verbatim — at ``rel_change=0.0`` the result is
    bit-identical to :func:`transfer_cost`.

    A caller that *knows* which links moved (a churn event, one mobile
    group) passes the pair mask as ``repriced`` and skips change detection
    entirely — true O(T·P) for P changed pairs instead of O(T·N²).  Without
    the hint, detection costs one pass over the tensor (still ~2× cheaper
    than the ~5 arithmetic passes of full pricing).  The ROADMAP regime:
    large-N swarms (N ≥ 50) with localized drift.
    """
    if rates.shape != ref_rates.shape:        # topology resized: full price
        full = transfer_cost(rates, rate_unit_bytes)
        return full, np.ones(full.shape, bool)
    r3 = rates[None] if rates.ndim == 2 else rates
    if repriced is None:
        ref3 = ref_rates[None] if ref_rates.ndim == 2 else ref_rates
        if rel_change > 0.0:
            with np.errstate(invalid="ignore"):
                diff = np.abs(r3 - ref3)
            diff = np.where(np.isnan(diff), 0.0, diff)  # inf==inf: self-link
            denom = np.maximum(np.minimum(r3, ref3), 1e-30)
            moved = diff > rel_change * denom  # covers 0 ↔ connected flips
        else:
            # Exact mode: a pure equality compare (no float arithmetic)
            # keeps detection far cheaper than the divides it saves;
            # inf == inf on self-links, so the diagonal never trips it.
            moved = r3 != ref3
        repriced = moved.any(axis=0)
    else:
        repriced = repriced.copy()
    np.fill_diagonal(repriced, False)         # same node: always 0 transfer
    spb = ref_spb.copy()
    ii, kk = np.nonzero(repriced)
    if ii.size == 0:
        return spb, repriced
    unit = 1.0 / rate_unit_bytes
    vals = np.zeros(ii.size)
    for t in range(r3.shape[0]):
        rv = r3[t][ii, kk]
        vals += np.where(rv > 0, unit / np.maximum(rv, 1e-30), _BIG)
    spb[ii, kk] = vals
    return spb, repriced


@dataclasses.dataclass
class Solution:
    assign: np.ndarray           # (R, M) node index per (request, layer)
    objective: float             # communication latency (paper objective)
    status: str                  # "optimal" | "feasible" | "rejected:<n>"
    solve_time_s: float
    admitted: np.ndarray         # (R,) bool — False = request rejected
    solver: str = "ilp"
    dp_stats: "ResolveStats | None" = None  # sparse-DP provenance (k, ladder)

    @property
    def n_admitted(self) -> int:
        return int(self.admitted.sum())


# ---------------------------------------------------------------------------
# ILP construction
# ---------------------------------------------------------------------------

class _Index:
    """Flat variable indexing: α block then γ block."""

    def __init__(self, R: int, N: int, M: int):
        self.R, self.N, self.M = R, N, M
        self.n_alpha = R * N * M
        # γ over r, j ∈ {1..M-1}, ordered pairs i≠k
        self.pairs = [(i, k) for i in range(N) for k in range(N) if i != k]
        self.n_gamma = R * (M - 1) * len(self.pairs)
        self.n_vars = self.n_alpha + self.n_gamma
        self._pair_id = {p: q for q, p in enumerate(self.pairs)}

    def a(self, r: int, i: int, j: int) -> int:
        return (r * self.N + i) * self.M + j

    def g(self, r: int, j: int, i: int, k: int) -> int:
        q = self._pair_id[(i, k)]
        return self.n_alpha + (r * (self.M - 1) + j) * len(self.pairs) + q


def _build_objective(prob: Problem, idx: "_Index", *,
                     include_compute: bool,
                     spb: np.ndarray | None = None) -> np.ndarray:
    """Rate-dependent objective coefficients (Eq. 12 + 13), vectorized.

    This is the ONLY part of the ILP that depends on the rate matrix, so
    epoch re-solves rebuild just this vector and reuse the cached sparse
    constraint matrix (see :class:`IncrementalSolver`)."""
    R, N, M = idx.R, idx.N, idx.M
    if spb is None:
        spb = prob.transfer_cost()      # (N,N) seconds/byte over horizon
    K = np.asarray(prob.profile.output_vector())    # K_j bytes
    Ks = prob.profile.input_bytes
    comp = np.asarray(prob.profile.compute_vector())

    c = np.zeros(idx.n_vars)
    ca = c[: idx.n_alpha].reshape(R, N, M)          # view
    # Source term t_s (Eq. 13): linear in α_{r,k,1}.
    for r in range(R):
        src = int(prob.sources[r])
        ca[r, :, 0] = Ks * spb[src, :]
        ca[r, src, 0] = 0.0
    # Inter-layer transfers (Eq. 12): γ_{r,i,k,j} · K_j / ρ_{i,k}.
    pi = np.fromiter((p[0] for p in idx.pairs), np.int64, len(idx.pairs))
    pk = np.fromiter((p[1] for p in idx.pairs), np.int64, len(idx.pairs))
    gam = K[: M - 1, None] * spb[pi, pk][None, :]   # (M-1, n_pairs)
    c[idx.n_alpha:] = np.broadcast_to(gam, (R, M - 1, len(idx.pairs))).ravel()
    if include_compute and prob.compute_speed is not None:
        # Heterogeneous-speed extension (linear): Σ α_{r,i,j}·c_j/speed_i.
        ca += comp[None, None, :] / prob.compute_speed[None, :, None]
    return c


def _build_constraints(prob: Problem, idx: "_Index", *, tight: bool):
    """Rate-INdependent constraint matrix (Eq. 4–6, 11) as a sparse
    LinearConstraint — cacheable across epochs (topology drift only moves
    the objective, never these rows)."""
    R, N, M = idx.R, idx.N, idx.M
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()

    rows, cols, vals, lo, hi = [], [], [], [], []
    row = 0

    def add_row(entries, lo_v, hi_v):
        nonlocal row
        for col, v in entries:
            rows.append(row)
            cols.append(col)
            vals.append(v)
        lo.append(lo_v)
        hi.append(hi_v)
        row += 1

    # Eq. 4 memory / Eq. 5 compute capacity per node.
    for i in range(N):
        add_row([(idx.a(r, i, j), mem[j]) for r in range(R) for j in range(M)],
                -np.inf, float(prob.mem_cap[i]))
    for i in range(N):
        add_row([(idx.a(r, i, j), comp[j]) for r in range(R) for j in range(M)],
                -np.inf, float(prob.comp_cap[i]))
    # Eq. 6 exactly-one per (r, j).
    for r in range(R):
        for j in range(M):
            add_row([(idx.a(r, i, j), 1.0) for i in range(N)], 1.0, 1.0)
    # Eq. 11 big-M linking.
    for r in range(R):
        for j in range(M - 1):
            for (i, k) in idx.pairs:
                g = idx.g(r, j, i, k)
                ai, ak = idx.a(r, i, j), idx.a(r, k, j + 1)
                add_row([(g, 1.0), (ai, -1.0), (ak, -1.0)], -1.0, np.inf)
                if tight:
                    add_row([(g, 1.0), (ai, -1.0)], -np.inf, 0.0)
                    add_row([(g, 1.0), (ak, -1.0)], -np.inf, 0.0)

    A = sp.csc_matrix((vals, (rows, cols)), shape=(row, idx.n_vars))
    return LinearConstraint(A, np.array(lo), np.array(hi))


def _build_ilp(prob: Problem, *, include_compute: bool, tight: bool,
               cache: dict | None = None):
    """Assemble (idx, c, constraints); ``cache`` (owned by the caller, e.g.
    :class:`IncrementalSolver`) memoizes the constraint structure keyed on
    instance shape + capacity vectors — valid because only the objective
    depends on the rates."""
    R, N, M = prob.n_requests, prob.n_nodes, prob.n_layers
    # The capacity rows also encode the profile's per-layer demands, so the
    # key must carry them — same-shaped instances with different profiles
    # must not share constraint structure.
    key = (R, N, M, tight, prob.mem_cap.tobytes(), prob.comp_cap.tobytes(),
           tuple(prob.profile.memory_vector()),
           tuple(prob.profile.compute_vector()))
    if cache is not None and key in cache:
        idx, constraints = cache[key]
    else:
        idx = _Index(R, N, M)
        constraints = _build_constraints(prob, idx, tight=tight)
        if cache is not None:
            cache[key] = (idx, constraints)
    c = _build_objective(prob, idx, include_compute=include_compute)
    return idx, c, constraints


def _solve_ilp_once(prob: Problem, *, include_compute: bool, tight: bool,
                    gamma_relaxed: bool, time_limit: float | None,
                    mip_rel_gap: float,
                    cache: dict | None = None) -> tuple[np.ndarray | None, float, str]:
    R, N, M = prob.n_requests, prob.n_nodes, prob.n_layers
    idx, c, constraints = _build_ilp(prob, include_compute=include_compute,
                                     tight=tight, cache=cache)
    # Normalize the objective so HiGHS tolerances (~1e-7 absolute) are far
    # below the cost scale — latencies can be microseconds on fast links.
    finite = np.abs(c[np.isfinite(c) & (np.abs(c) > 0) & (np.abs(c) < _BIG)])
    scale = 1.0 / finite.max() if finite.size else 1.0
    c = np.minimum(c * scale, 1e9)  # disconnected pairs stay priced out
    integrality = np.zeros(idx.n_vars)
    integrality[: idx.n_alpha] = 1
    if not gamma_relaxed:
        integrality[:] = 1
    opts: dict = {"mip_rel_gap": mip_rel_gap}
    if time_limit is not None:
        opts["time_limit"] = time_limit
    res = milp(c, constraints=constraints, integrality=integrality,
               bounds=Bounds(0.0, 1.0), options=opts)
    # status 0 = optimal; 1 = hit time/iteration limit (accept incumbent)
    if res.status not in (0, 1) or res.x is None:
        return None, float("inf"), "infeasible" if res.status == 2 else f"status{res.status}"
    alpha = res.x[: idx.n_alpha].reshape(R, N, M)
    assign = alpha.argmax(axis=1).astype(np.int64)  # (R, M)
    return (assign, float(res.fun) / scale,
            "optimal" if res.status == 0 else "feasible")


# ---------------------------------------------------------------------------
# Exact per-request DP (lattice shortest path) + sequential greedy-DP
# ---------------------------------------------------------------------------

def _dp_single_request(spb: np.ndarray, K: list[float], Ks: float, src: int,
                       mem: list[float], comp: list[float],
                       mem_left: np.ndarray, comp_left: np.ndarray,
                       compute_cost: np.ndarray | None) -> tuple[np.ndarray | None, float]:
    """Shortest path through the (layer, node) lattice for one request.

    State cost(j, i): min latency to have layer j's output resident on node i.
    Edge (j-1,k) → (j,i): K_{j-1}·spb[k,i] (0 if k == i).  Feasibility of
    putting layer j on node i uses *remaining* capacity — exact for a single
    request when the node never needs to split a single layer.
    """
    N, M = spb.shape[0], len(K)
    INF = float("inf")
    feas = np.zeros((M, N), bool)
    for j in range(M):
        feas[j] = (mem_left >= mem[j]) & (comp_left >= comp[j])
    cost = np.full((M, N), INF)
    back = np.full((M, N), -1, np.int64)
    for i in range(N):
        if feas[0, i]:
            cost[0, i] = 0.0 if i == src else Ks * spb[src, i]
            if compute_cost is not None:
                cost[0, i] += compute_cost[0, i]
    for j in range(1, M):
        # NOTE: single-request DP treats per-layer feasibility independently;
        # when one node hosts several layers of the SAME request the combined
        # load is checked post-hoc by the caller and repaired greedily.
        prev = cost[j - 1]
        step = prev[:, None] + np.array(K[j - 1]) * spb  # (k→i)
        if compute_cost is not None:
            step = step + compute_cost[j][None, :]
        step[:, ~feas[j]] = INF
        back[j] = step.argmin(axis=0)
        cost[j] = step[back[j], np.arange(N)]
    end = int(np.argmin(cost[-1]))
    if not np.isfinite(cost[-1, end]):
        return None, INF
    path = np.zeros(M, np.int64)
    path[-1] = end
    for j in range(M - 1, 0, -1):
        path[j - 1] = back[j, path[j]]
    return path, float(cost[-1, end])


def default_sparse_k(n_nodes: int) -> int:
    """Default per-layer candidate budget of the sparse DP: ⌈√N⌉ keeps the
    pruned transition scan O(M·N) overall (N candidates scored, √N² kept),
    with a floor so tiny swarms still see a meaningful candidate set."""
    return max(4, int(np.ceil(np.sqrt(n_nodes))))


class _SparseCounters:
    """Mutable tally of what the sparse ladder actually did (one solve)."""

    __slots__ = ("n_runs", "n_scanned", "n_dense_equiv", "n_escalations",
                 "n_dense_fallback", "n_batched", "n_jit_compiles")

    def __init__(self):
        self.n_runs = 0             # DP kernel invocations (incl. repairs)
        self.n_scanned = 0          # lattice transitions actually scanned
        self.n_dense_equiv = 0      # what the dense kernel would have scanned
        self.n_escalations = 0      # k-doubling retries
        self.n_dense_fallback = 0   # requests that hit the dense last resort
        self.n_batched = 0          # requests served by the batched fast path
        self.n_jit_compiles = 0     # XLA compiles triggered inside the solve

    def wrap(self, kernel: Callable, per_run: int, dense_per_run: int):
        """Instrument ``kernel`` so every invocation (the repair loop re-runs
        it) is charged ``per_run`` scanned transitions."""
        def run(*args):
            self.n_runs += 1
            self.n_scanned += per_run
            self.n_dense_equiv += dense_per_run
            return kernel(*args)
        return run

    @property
    def pruned_fraction(self) -> float:
        if self.n_dense_equiv == 0:
            return 0.0
        return 1.0 - self.n_scanned / self.n_dense_equiv


def _dp_single_request_sparse(spb: np.ndarray, K: list[float], Ks: float,
                              src: int, mem: list[float], comp: list[float],
                              mem_left: np.ndarray, comp_left: np.ndarray,
                              compute_cost: np.ndarray | None, k: int,
                              head: np.ndarray | None = None,
                              consts: tuple | None = None
                              ) -> tuple[np.ndarray | None, float]:
    """Pruned lattice DP: per layer, only the ``k`` best candidate nodes.

    Candidates are ranked by seconds/byte from the request's source under the
    residual topology (cheap proxy for how expensive it is to route
    activations through the node) with a small capacity-headroom tiebreak
    (``head``; recomputed from the residual capacities when not supplied),
    feasibility-masked per layer.  Candidate lists are kept in ascending node
    order so that at k ≥ N the argmin tie-breaking — and therefore the
    returned path — is bit-identical to :func:`_dp_single_request`.

    The formulation is one vectorized pass: an (M, N) feasibility mask, one
    masked argpartition, an (M-1, k, k) gather of the transition
    sub-matrices with the infeasibility penalty and compute cost pre-added,
    and a recurrence that touches k² entries per layer instead of N².
    ``consts`` carries per-solve invariants (K vector, per-layer demands,
    score scale) so repeated calls skip their recomputation.
    """
    if consts is None:
        consts = _sparse_consts(spb, K, mem, comp)
    if head is None:
        head = (mem_left / max(float(mem_left.max()), 1e-30)
                + comp_left / max(float(comp_left.max()), 1e-30))
    cand, valid = _sparse_select(spb, src, mem_left, comp_left, head,
                                 consts, k)
    return _sparse_run(spb, Ks, src, compute_cost, cand, valid, consts)


def _sparse_consts(spb: np.ndarray, K: list[float], mem: list[float],
                   comp: list[float]) -> tuple:
    """Per-solve invariants of the sparse kernel: (K, m, c vectors and the
    candidate-score normalizer 1/max finite spb)."""
    finite = spb[(spb > 0) & (spb < _BIG)]
    scale = float(finite.max()) if finite.size else 1.0
    return (np.asarray(K, float), np.asarray(mem, float),
            np.asarray(comp, float), 1.0 / scale)


def _sparse_select(spb: np.ndarray, src: int, mem_left: np.ndarray,
                   comp_left: np.ndarray, head: np.ndarray, consts: tuple,
                   k: int) -> tuple[np.ndarray, np.ndarray]:
    """Candidate selection of the pruned DP: per layer, the k best feasible
    nodes by score, in ascending node order.  Returns (cand, valid) — the
    (M, k) candidate node ids and their per-layer feasibility bits.  The DP
    output is a pure function of these two arrays (given the fixed spb and
    compute costs), which is what makes cached stage outputs certifiable by
    an equality check on them."""
    _, mem_a, comp_a, inv_scale = consts
    N, M = spb.shape[0], mem_a.shape[0]
    kk = int(min(k, N))
    feas = ((mem_left[None, :] >= mem_a[:, None])
            & (comp_left[None, :] >= comp_a[:, None]))      # (M, N)
    score = spb[src] * inv_scale - 1e-3 * head  # cost dominates, headroom ties
    masked = np.where(feas, score[None, :], np.inf)         # (M, N)
    if kk < N:
        cand = np.argpartition(masked, kk - 1, axis=1)[:, :kk]
        cand.sort(axis=1)                   # ascending node ids (dense tie-break)
    else:
        cand = np.broadcast_to(np.arange(N), (M, N))
    valid = feas[np.arange(M)[:, None], cand]               # (M, kk)
    return cand, valid


def _sparse_select_batch(spb: np.ndarray, srcs: np.ndarray,
                         mem_left: np.ndarray, comp_left: np.ndarray,
                         head: np.ndarray, consts: tuple, k: int
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized :func:`_sparse_select` over S sources at once.

    Produces per source *exactly* the arrays the scalar selection produces —
    the feasibility mask is source-independent (computed once instead of S
    times), the score differs per source only through its ``spb`` row, and
    ``np.argpartition``/``sort`` act on each (source, layer) slice
    independently, so the (S, M, k) result rows are elementwise identical to
    S scalar calls.  This is what makes one batch dispatch's selection cost
    O(S + M·N) Python-side instead of S × O(M·N).
    """
    _, mem_a, comp_a, inv_scale = consts
    N, M = spb.shape[0], mem_a.shape[0]
    kk = int(min(k, N))
    feas = ((mem_left[None, :] >= mem_a[:, None])
            & (comp_left[None, :] >= comp_a[:, None]))      # (M, N)
    score = spb[srcs] * inv_scale - 1e-3 * head[None, :]    # (S, N)
    masked = np.where(feas[None], score[:, None, :], np.inf)  # (S, M, N)
    if kk < N:
        cand = np.argpartition(masked, kk - 1, axis=2)[:, :, :kk]
        cand.sort(axis=2)
    else:
        cand = np.broadcast_to(np.arange(N), (len(srcs), M, N)).copy()
    valid = feas[np.arange(M)[None, :, None], cand]         # (S, M, kk)
    return cand, valid


def _sparse_run(spb: np.ndarray, Ks: float, src: int,
                compute_cost: np.ndarray | None, cand: np.ndarray,
                valid: np.ndarray, consts: tuple
                ) -> tuple[np.ndarray | None, float]:
    """The pruned DP recurrence over pre-selected candidates: an (M-1, k, k)
    transition block with the infeasibility penalty (and target compute cost)
    folded in once, then a k²-per-layer min-plus sweep."""
    Kv = consts[0]
    M, kk = cand.shape
    pen = np.where(valid, 0.0, np.inf)                      # (M, kk) additive
    cost = Ks * spb[src, cand[0]] + pen[0]  # spb[src, src] == 0: free at src
    if compute_cost is not None:
        cost = cost + compute_cost[0, cand[0]]
    trans = Kv[:M - 1, None, None] * spb[cand[:-1, :, None], cand[1:, None, :]]
    trans += pen[1:, None, :]
    if compute_cost is not None:
        trans += compute_cost[np.arange(1, M)[:, None], cand[1:]][:, None, :]
    back = np.empty((M, kk), np.int64)
    rng_kk = np.arange(kk)
    for j in range(1, M):
        step = cost[:, None] + trans[j - 1]                 # (kk prev, kk cur)
        b = step.argmin(axis=0)
        back[j] = b
        cost = step[b, rng_kk]
    end = int(np.argmin(cost))
    if not np.isfinite(cost[end]):
        return None, float("inf")
    path = np.zeros(M, np.int64)
    idx = end
    path[M - 1] = cand[M - 1, idx]
    for j in range(M - 1, 0, -1):
        idx = int(back[j, idx])
        path[j - 1] = cand[j - 1, idx]
    return path, float(cost[end])


def _repair_capacity(path: np.ndarray, mem: list[float], comp: list[float],
                     mem_left: np.ndarray, comp_left: np.ndarray) -> bool:
    """Check a DP path against *joint* per-node load; True if it fits."""
    N = mem_left.shape[0]
    m_use = np.zeros(N)
    c_use = np.zeros(N)
    for j, i in enumerate(path):
        m_use[i] += mem[j]
        c_use[i] += comp[j]
    return bool(np.all(m_use <= mem_left + 1e-9) and np.all(c_use <= comp_left + 1e-9))


CAPACITY_REPAIRS = ("halve", "gentle")


def _gentle_shrink(adv: np.ndarray, busy: int, load: float,
                   demands: list[float]) -> None:
    """The gentle capacity-repair step: advertise ``load − min hosted layer``
    on the overloaded node instead of halving — the node sheds just enough
    for its smallest hosted layer to move, rather than (potentially) being
    zeroed while it could still host one layer.  When that target would not
    strictly shrink the advertisement (with ≥ 2 hosted layers it never
    excludes any of them), peel the *largest* hosted layer by advertising
    one ulp below its demand — guaranteed progress in the hosted-set
    lattice, same 4N iteration bound as halving."""
    new = min(adv[busy], load - min(demands))
    if new >= adv[busy]:
        new = np.nextafter(max(demands), 0.0)
    adv[busy] = max(new, 0.0)


def _place_request(spb: np.ndarray, K: list[float], Ks: float, src: int,
                   mem: list[float], comp: list[float],
                   mem_left: np.ndarray, comp_left: np.ndarray,
                   compute_cost: np.ndarray | None,
                   kernel: Callable = _dp_single_request,
                   capacity_repair: str = "halve") -> tuple[np.ndarray | None, float]:
    """Place ONE request against residual capacity: lattice DP + repair loop.

    The lattice DP checks per-layer feasibility, not the joint within-request
    load; the repair loop iteratively shrinks the advertised memory AND
    compute of the most-overloaded node and re-plans — forcing the DP to
    spread until the joint check passes.  Shared by the cold greedy-DP solve
    and the incremental warm re-solve.  Does NOT mutate mem_left/comp_left.

    ``kernel`` is the single-request DP — the dense N×N scan by default, or a
    pruned k-candidate kernel (the sparse solver runs the same repair loop,
    only the inner shortest-path search changes).

    ``capacity_repair`` picks the shrink rule: ``"halve"`` (default, the
    pinned-baseline rule) cuts the busiest node's advertised capacity by 2×
    and zeroes it below the smallest layer demand — which can exclude a node
    that still fit one layer; ``"gentle"`` sheds only ``load − min hosted
    layer`` (:func:`_gentle_shrink`), admitting strictly more under
    contention.
    """
    if capacity_repair not in CAPACITY_REPAIRS:
        raise ValueError(f"unknown capacity_repair {capacity_repair!r}; "
                         f"one of {CAPACITY_REPAIRS}")
    N = spb.shape[0]
    path, cost = kernel(spb, K, Ks, src, mem, comp,
                        mem_left, comp_left, compute_cost)
    mem_adv = mem_left.copy()
    comp_adv = comp_left.copy()
    for _ in range(4 * N):
        if path is None or _repair_capacity(path, mem, comp, mem_left,
                                            comp_left):
            break
        m_load = np.zeros(N)
        c_load = np.zeros(N)
        for j, i in enumerate(path):
            m_load[i] += mem[j]
            c_load[i] += comp[j]
        m_over = m_load - mem_left
        c_over = c_load - comp_left
        if m_over.max() >= c_over.max() / max(comp_left.max(), 1e-9) * \
                max(mem_left.max(), 1e-9):
            busy = int(m_over.argmax())
            if capacity_repair == "gentle":
                _gentle_shrink(mem_adv, busy, m_load[busy],
                               [mem[j] for j, i in enumerate(path)
                                if i == busy and mem[j] > 0] or [0.0])
            else:
                mem_adv[busy] = max(mem_adv[busy] / 2.0, 0.0)
                if mem_adv[busy] < min((m for m in mem if m > 0), default=0):
                    mem_adv[busy] = 0.0
        else:
            busy = int(c_over.argmax())
            if capacity_repair == "gentle":
                _gentle_shrink(comp_adv, busy, c_load[busy],
                               [comp[j] for j, i in enumerate(path)
                                if i == busy and comp[j] > 0] or [0.0])
            else:
                comp_adv[busy] = max(comp_adv[busy] / 2.0, 0.0)
                if comp_adv[busy] < min((c for c in comp if c > 0), default=0):
                    comp_adv[busy] = 0.0
        path, cost = kernel(spb, K, Ks, src, mem, comp,
                            mem_adv, comp_adv, compute_cost)
    if path is None or not _repair_capacity(path, mem, comp, mem_left,
                                            comp_left):
        return None, float("inf")
    return path, cost


class _SparsePlacer:
    """Sequential sparse placement over one priced topology.

    Owns the two levers that make the k-candidate DP fast at N ≥ 50:

    * **The fallback ladder** (admission parity with the dense DP): a request
      the pruned kernel rejects — no feasible path inside the candidate
      sets, only one riding a ``_BIG``-priced (disconnected) link, or only
      one over the ``max_path_cost`` admission bar — retries with k doubled
      and, once k ≥ N, the dense kernel.  Every request's admission
      decision is therefore identical to
      ``solver="dp"``'s under the same residual capacities; only the *path*
      of an admitted request may differ while k < N.
    * **Per-source stage memoization**: the pruned DP's output is a pure
      function of the selected (candidates, feasibility) arrays — so each
      ladder stage's unrepaired output is cached per source and *certified*
      on replay by re-running only the cheap candidate selection and
      comparing: equal arrays ⇒ the DP would reproduce the cached path
      bit-for-bit, so the k²-transition sweep is skipped.  (The headroom
      tiebreak entering the selection score is frozen per *feasibility
      epoch* — bumped whenever a commit flips any per-layer feasibility
      bit — keeping selection deterministic between flips; dense-kernel
      stages, which read the full topology, are certified by epoch equality
      instead.)  A certified path is accepted only when it passes the joint
      residual check *right now*; anything residual-dependent (a failed
      fit, a repaired path) falls back to a full ladder re-run.  Because
      residuals only shrink during a solve, a cached stage that failed its
      fit check can never start fitting again — replay is exactly what a
      fresh run would compute, minus the DP sweeps.

    Residual capacity arrays are the caller's; :meth:`commit` mutates them
    in place so the caller observes every reservation.
    """

    def __init__(self, spb: np.ndarray, K: list[float], Ks: float,
                 mem: list[float], comp: list[float],
                 mem_left: np.ndarray, comp_left: np.ndarray,
                 compute_cost: np.ndarray | None, *, k: int,
                 max_path_cost: float | None = None,
                 counters: _SparseCounters | None = None,
                 capacity_repair: str = "halve"):
        self.spb = spb
        self.K, self.Ks, self.mem, self.comp = K, Ks, mem, comp
        self.mem_left, self.comp_left = mem_left, comp_left
        self.compute_cost = compute_cost
        self.k = max(1, int(k))
        self.max_path_cost = max_path_cost
        self.capacity_repair = capacity_repair
        self.counters = counters
        self.consts = _sparse_consts(spb, K, mem, comp)
        _, self._mem_a, self._comp_a, _ = self.consts
        self._feas = self._feas_of(np.arange(spb.shape[0]))   # (M, N)
        self._head = self._headroom()
        self._epoch = 0
        # src → (epoch, [(lvl, cand, valid, p0, cost0, is_dense), ...])
        self._cache: dict[int, tuple[int, list]] = {}
        self.n_cache_hits = 0

    # -- epoch bookkeeping --------------------------------------------------

    def _feas_of(self, cols: np.ndarray) -> np.ndarray:
        return ((self.mem_left[cols][None, :] >= self._mem_a[:, None])
                & (self.comp_left[cols][None, :] >= self._comp_a[:, None]))

    def _headroom(self) -> np.ndarray:
        return (self.mem_left / max(float(self.mem_left.max()), 1e-30)
                + self.comp_left / max(float(self.comp_left.max()), 1e-30))

    def _fits(self, path: np.ndarray) -> bool:
        return _repair_capacity(path, self.mem, self.comp,
                                self.mem_left, self.comp_left)

    def commit(self, path: np.ndarray) -> None:
        """Reserve a placed path's capacity; advance the feasibility epoch
        when any (layer, node) feasibility bit flips."""
        for j, i in enumerate(path):
            self.mem_left[i] -= self.mem[j]
            self.comp_left[i] -= self.comp[j]
        cols = np.unique(path)
        fresh = self._feas_of(cols)
        if not np.array_equal(fresh, self._feas[:, cols]):
            self._feas[:, cols] = fresh
            self._head = self._headroom()
            self._epoch += 1

    # -- placement ----------------------------------------------------------

    def place(self, src: int) -> tuple[np.ndarray | None, float]:
        """Ladder placement for one request (cache replay when certified)."""
        ent = self._cache.get(src)
        if ent is None:
            return self._ladder(src)
        epoch, stages = ent
        for lvl, cand, valid, p0, cost0, is_dense in stages:
            if is_dense:
                if epoch != self._epoch:    # dense reads the full topology
                    return self._ladder(src)
            else:
                now = _sparse_select(self.spb, src, self.mem_left,
                                     self.comp_left, self._head, self.consts,
                                     lvl)
                if not (np.array_equal(now[0], cand)
                        and np.array_equal(now[1], valid)):
                    return self._ladder(src)    # selection moved: re-run
            # Stage output certified identical to a fresh kernel run.
            if p0 is None:
                continue                    # no path through the candidates
            if not is_dense and (cost0 >= _BIG
                                 or (self.max_path_cost is not None
                                     and cost0 > self.max_path_cost)):
                continue                    # repair only raises cost: the
                                            # fresh ladder would escalate too
            if self._fits(p0):
                self.n_cache_hits += 1
                return p0, cost0
            return self._ladder(src)        # residual-dependent: re-run
        # Every stage certified and skipped ⇒ the fresh ladder would reject.
        self.n_cache_hits += 1
        return None, float("inf")

    def _ladder(self, src: int) -> tuple[np.ndarray | None, float]:
        N, M = self.spb.shape[0], len(self.K)
        dense_per_run = (M - 1) * N * N
        counters = self.counters
        stages: list[tuple] = []
        result: tuple[np.ndarray | None, float] = (None, float("inf"))
        kk = self.k
        levels = []
        while kk < N:
            levels.append(kk)
            kk *= 2
        levels.append(N)                    # dense last resort
        for lvl in levels:
            if lvl >= N:                    # dense last resort
                base: Callable = _dp_single_request
                if counters is not None:
                    counters.n_dense_fallback += 1
                    base = counters.wrap(base, dense_per_run, dense_per_run)
                first: list = []

                def kernel(*args, _base=base, _first=first):
                    out = _base(*args)
                    if not _first:
                        _first.append(out)  # the unrepaired stage output p0
                    return out

                path, cost = _place_request(self.spb, self.K, self.Ks, src,
                                            self.mem, self.comp,
                                            self.mem_left, self.comp_left,
                                            self.compute_cost, kernel=kernel,
                                            capacity_repair=self.capacity_repair)
                stages.append((lvl, None, None, *first[0], True))
                result = (path, cost)
                break
            cand, valid = _sparse_select(self.spb, src, self.mem_left,
                                         self.comp_left, self._head,
                                         self.consts, lvl)
            if counters is not None:
                counters.n_runs += 1
                counters.n_scanned += (M - 1) * lvl * lvl
                counters.n_dense_equiv += dense_per_run
            p0, cost0 = _sparse_run(self.spb, self.Ks, src,
                                    self.compute_cost, cand, valid,
                                    self.consts)
            stages.append((lvl, cand, valid, p0, cost0, False))
            # Escalate off a ``_BIG``-priced path unconditionally: the pruned
            # candidate set may have missed a finite relay (e.g. the single
            # bridge node between two clusters) that a wider set — or the
            # dense last resort — still finds.  Also escalate when the path
            # is over the admission bar; repair only raises cost, so neither
            # skip can hide a path this stage could have admitted.
            too_dear = (cost0 >= _BIG
                        or (self.max_path_cost is not None
                            and cost0 > self.max_path_cost))
            if p0 is not None and not too_dear:
                if self._fits(p0):
                    result = (p0, cost0)
                    break
                # Joint within-request overload: run the full repair loop
                # with the same pruned kernel (recomputes p0, then spreads).
                base = functools.partial(_dp_single_request_sparse, k=lvl,
                                         head=self._head, consts=self.consts)
                if counters is not None:
                    base = counters.wrap(base, (M - 1) * lvl * lvl,
                                         dense_per_run)
                path, cost = _place_request(self.spb, self.K, self.Ks, src,
                                            self.mem, self.comp,
                                            self.mem_left, self.comp_left,
                                            self.compute_cost, kernel=base,
                                            capacity_repair=self.capacity_repair)
                if path is not None and (self.max_path_cost is None
                                         or cost <= self.max_path_cost):
                    result = (path, cost)
                    break
            if counters is not None:
                counters.n_escalations += 1
        self._cache[src] = (self._epoch, stages)
        return result


def _fits_joint(path: np.ndarray, mem: list[float], comp: list[float],
                mem_left: np.ndarray, comp_left: np.ndarray) -> bool:
    """Path-local equivalent of :func:`_repair_capacity`: a path loads at
    most M distinct nodes, so only those need the joint residual check —
    O(M) instead of the O(N) full-array scan (the batched fast path's
    per-request cost must not scale with swarm size)."""
    m_use: dict[int, float] = {}
    c_use: dict[int, float] = {}
    for j, i in enumerate(path):
        i = int(i)
        m_use[i] = m_use.get(i, 0.0) + mem[j]
        c_use[i] = c_use.get(i, 0.0) + comp[j]
    return all(m_use[i] <= mem_left[i] + 1e-9
               and c_use[i] <= comp_left[i] + 1e-9 for i in m_use)


def _place_batch(placer: _SparsePlacer,
                 sources: list[int]) -> list[tuple[np.ndarray | None, float]]:
    """Greedy sequential placement with the batched kernel fast path.

    Returns per-request ``(path, cost)`` — ``(None, inf)`` for rejections —
    with commits applied, such that decisions (admission AND paths) are
    bit-identical to calling ``placer.place(src)`` + bar check + commit per
    request in order.

    One jitted dispatch (:func:`repro.core.batch_dp.solve_batch`) precomputes
    the base-ladder-level DP of every *distinct* pending source against the
    current residuals.  A request may consume its precomputed row only while
    **certified**: the feasibility epoch is unchanged since the dispatch, so
    candidate selection is provably identical (selection reads the residuals
    only through the feasibility bits — unflipped — and the headroom
    tiebreak frozen per epoch: the :class:`_SparsePlacer` certification
    argument).  A certified row is accepted exactly when the sequential base
    stage would have been (finite cost, under the admission bar, joint
    residual fit); a non-accepted row — no finite path, too dear, fit
    failure — falls back to ``placer.place``'s full ladder against the
    current residuals, just as the sequential solve escalates.  When a
    commit *does* bump the epoch, the remaining requests are re-batched in
    one fresh dispatch rather than de-certifying one by one: per bump that
    costs |distinct sources| selections + one kernel call, where the
    sequential path pays a selection per request.

    Between dispatches the fast path never touches numpy: residual updates
    live in Python *shadow dicts* overlaid on ``placer.mem_left`` /
    ``comp_left`` (Python floats are IEEE doubles, so the per-layer
    subtraction fold is bit-identical to the numpy scalar loop in
    :meth:`_SparsePlacer.commit`), and the epoch-flip test is two ``bisect``
    calls per touched node against the sorted per-layer requirement
    thresholds — the count of thresholds ≤ residual determines that node's
    feasibility bits exactly, so equal counts on both resources certify "no
    bit flipped" without building the (M, |cols|) bit arrays.  A *possible*
    flip defers to ``placer.commit`` (after flushing the shadows), which
    performs the exact joint-bit comparison and the epoch bump.
    """
    from bisect import bisect_right

    from . import batch_dp

    counters = placer.counters
    mem_l, comp_l = placer.mem, placer.comp          # per-layer demands
    mem_left, comp_left = placer.mem_left, placer.comp_left
    mem_ts = sorted(float(x) for x in placer._mem_a)   # bit-pattern keys:
    comp_ts = sorted(float(x) for x in placer._comp_a)  # count(ts <= res)
    max_cost = placer.max_path_cost
    R = len(sources)
    out: list[tuple[np.ndarray | None, float]] = []
    i = 0
    top_m, top_c = None, None
    while i < R:
        # Batch the distinct sources still pending at the current residuals.
        uniq: list[int] = []
        row_of: dict[int, int] = {}
        for s in sources[i:]:
            if s not in row_of:
                row_of[s] = len(uniq)
                uniq.append(s)
        cand, valid = _sparse_select_batch(placer.spb,
                                           np.asarray(uniq, np.int64),
                                           placer.mem_left, placer.comp_left,
                                           placer._head, placer.consts,
                                           placer.k)
        c0 = batch_dp.compile_count()
        paths0, costs0 = batch_dp.solve_batch(
            placer.spb, placer.Ks, placer.compute_cost,
            np.asarray(uniq, np.int64), cand, valid, placer.consts)
        batch_epoch = placer._epoch
        if counters is not None:
            _, M, kk = cand.shape
            N = placer.spb.shape[0]
            counters.n_runs += len(uniq)
            counters.n_scanned += len(uniq) * (M - 1) * kk * kk
            counters.n_dense_equiv += len(uniq) * (M - 1) * N * N
            counters.n_jit_compiles += batch_dp.compile_count() - c0
        # Per-row precomputation shared by every request on the row: the
        # layer-by-layer demand sequence (the commit fold) and the per-node
        # aggregated demand in first-visit order (the _fits_joint fold).
        row_bad, row_layers, row_agg, row_out = [], [], [], []
        for q in range(len(uniq)):
            p = paths0[q]
            bad = (p is None or costs0[q] >= _BIG
                   or (max_cost is not None and costs0[q] > max_cost))
            row_bad.append(bad)
            if bad:
                row_layers.append(None)
                row_agg.append(None)
                row_out.append(None)
                continue
            pl = p.tolist()
            row_layers.append(list(zip(pl, mem_l, comp_l)))
            agg: dict[int, list[float]] = {}
            for j, node in enumerate(pl):
                a = agg.get(node)
                if a is None:
                    agg[node] = [mem_l[j], comp_l[j]]
                else:
                    a[0] += mem_l[j]
                    a[1] += comp_l[j]
            row_agg.append([(n, a[0], a[1]) for n, a in agg.items()])
            row_out.append((p, float(costs0[q])))
        if top_m is None:
            top_m, top_c = mem_ts[-1], comp_ts[-1]
        sh_m: dict[int, float] = {}      # shadow residuals (node → value);
        sh_c: dict[int, float] = {}      # truth overlay on mem/comp_left

        def flush():
            if sh_m:
                ks = list(sh_m)
                mem_left[ks] = [sh_m[n] for n in ks]
                comp_left[ks] = [sh_c[n] for n in ks]
                sh_m.clear()
                sh_c.clear()

        while i < R:
            if placer._epoch != batch_epoch:
                break                       # stale rows: re-batch the rest
            q = row_of[sources[i]]
            if not row_bad[q]:
                # Joint fit (== _fits_joint) against the shadowed residuals.
                olds = []
                ok = True
                for node, um, uc in row_agg[q]:
                    om = sh_m.get(node)
                    if om is None:
                        om = float(mem_left[node])
                        oc = float(comp_left[node])
                    else:
                        oc = sh_c[node]
                    olds.append((node, om, oc))
                    if um > om + 1e-9 or uc > oc + 1e-9:
                        ok = False
                        break
                if ok:
                    # Commit: per-layer subtraction in path order (the exact
                    # fold _SparsePlacer.commit performs).
                    cur_m = {n: om for n, om, _ in olds}
                    cur_c = {n: oc for n, _, oc in olds}
                    for node, mj, cj in row_layers[q]:
                        cur_m[node] -= mj
                        cur_c[node] -= cj
                    flip = False
                    for node, om, oc in olds:
                        nm, nc = cur_m[node], cur_c[node]
                        # Demands only shrink residuals: new ≥ top ⇒ old ≥
                        # top ⇒ every bit stays set — skip the bisects.
                        if ((nm < top_m and bisect_right(mem_ts, nm)
                                != bisect_right(mem_ts, om))
                                or (nc < top_c and bisect_right(comp_ts, nc)
                                    != bisect_right(comp_ts, oc))):
                            flip = True
                            break
                    if flip:
                        # A bit may have flipped: take the exact path.
                        flush()
                        placer.commit(paths0[q])
                    else:
                        sh_m.update(cur_m)
                        sh_c.update(cur_c)
                    if counters is not None:
                        counters.n_batched += 1
                    out.append(row_out[q])
                    i += 1
                    continue
            # Row rejected (no finite path / too dear / fit failure): the
            # sequential solve escalates the ladder from current residuals.
            flush()
            path, cost = placer.place(int(sources[i]))
            if path is not None and (max_cost is None or cost <= max_cost):
                placer.commit(path)
                out.append((path, cost))
            else:
                out.append((None, float("inf")))
            i += 1
        flush()
    return out


def _path_cost(spb: np.ndarray, K: list[float], Ks: float, src: int,
               path: np.ndarray,
               compute_cost: np.ndarray | None = None) -> float:
    """Objective contribution of one placed path under a given spb — the same
    quantity the DP minimizes, recomputable after the rates drift."""
    cost = 0.0 if path[0] == src else Ks * spb[src, int(path[0])]
    for j in range(len(path) - 1):
        if path[j + 1] != path[j]:
            cost += K[j] * spb[int(path[j]), int(path[j + 1])]
    if compute_cost is not None:
        for j, i in enumerate(path):
            cost += compute_cost[j, int(i)]
    return float(cost)


def improvement_bound(prob: Problem, assign: np.ndarray,
                      admitted: np.ndarray, *, sparse_k: int | None = None,
                      include_compute: bool = False
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Per-request slack-capacity DP lower bound on re-placement cost.

    The epoch keep rule re-places *touched* requests only; this quantifies
    what that conservatism costs.  For each admitted request the bound
    solves the single-request lattice DP against the request's **slack
    capacity** — the residual after every admitted reservation, plus the
    request's own reservation released (what a re-place of just this
    request could actually use).  The per-layer-feasibility relaxation
    means the dense DP value lower-bounds any feasible re-placement; the
    k-candidate pruned kernel (``sparse_k``; :func:`default_sparse_k` when
    None, exact at ``k ≥ N``) may miss the optimum's nodes, so the bound is
    clipped at the current path cost — drift is then never negative, only
    possibly under-reported.

    Returns ``(bound_s, current_s)`` over plan rows; non-admitted rows are
    zero.  :func:`placement_drift` is the difference the epoch hook logs.
    """
    spb = prob.transfer_cost()
    K = prob.profile.output_vector()
    Ks = prob.profile.input_bytes
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()
    mem_a = np.asarray(mem, float)
    comp_a = np.asarray(comp, float)
    compute_cost = None
    if include_compute and prob.compute_speed is not None:
        compute_cost = (np.asarray(comp)[:, None]
                        / prob.compute_speed[None, :]) * prob.horizon()

    rows = [r for r in range(prob.n_requests) if admitted[r]]
    mem_left = prob.mem_cap.astype(float).copy()
    comp_left = prob.comp_cap.astype(float).copy()
    for r in rows:
        np.subtract.at(mem_left, assign[r], mem_a)
        np.subtract.at(comp_left, assign[r], comp_a)

    k = sparse_k if sparse_k is not None else default_sparse_k(prob.n_nodes)
    consts = _sparse_consts(spb, K, mem, comp)
    bound = np.zeros(prob.n_requests)
    current = np.zeros(prob.n_requests)
    for r in rows:
        path = assign[r]
        src = int(prob.sources[r])
        slack_m = mem_left.copy()
        slack_c = comp_left.copy()
        np.add.at(slack_m, path, mem_a)       # release own reservation
        np.add.at(slack_c, path, comp_a)
        cur = _path_cost(spb, K, Ks, src, path, compute_cost)
        _, cost = _dp_single_request_sparse(
            spb, K, Ks, src, mem, comp, slack_m, slack_c, compute_cost,
            k, consts=consts)
        current[r] = cur
        bound[r] = min(cost, cur)
    return bound, current


def placement_drift(prob: Problem, assign: np.ndarray, admitted: np.ndarray,
                    *, sparse_k: int | None = None,
                    include_compute: bool = False) -> np.ndarray:
    """(R,) how far each kept placement drifted from its slack-capacity
    optimum: current path cost − :func:`improvement_bound` (≥ 0; zero for
    non-admitted rows and for requests still at their bound)."""
    bound, current = improvement_bound(prob, assign, admitted,
                                       sparse_k=sparse_k,
                                       include_compute=include_compute)
    return np.maximum(current - bound, 0.0)


def _solve_dp(prob: Problem, *, include_compute: bool,
              max_path_cost: float | None = None,
              sparse_k: int | None = None, batch_solve: bool = False,
              capacity_repair: str = "halve"
              ) -> tuple[np.ndarray, float, np.ndarray, "ResolveStats | None"]:
    """Sequential greedy-DP: requests placed one at a time (exact per request,
    greedy across requests).  Returns (assign, total_comm_latency, admitted,
    stats); rejected rows carry the ``-1`` sentinel.  ``stats`` is None for
    the dense scan and a :class:`ResolveStats` carrying the pruning telemetry
    (k, escalations, dense fallbacks, pruned fraction) when ``sparse_k`` is
    set.

    ``max_path_cost`` rejects a request whose cheapest feasible path still
    costs more — i.e. it would ride a disconnected (``_BIG``-priced) link.
    The paper's admission semantics: serve over a dead link is an outage, so
    such requests are rejected rather than placed (§IV-A / Fig. 13)."""
    t0 = time.perf_counter()
    R, N, M = prob.n_requests, prob.n_nodes, prob.n_layers
    spb = prob.transfer_cost()
    K = prob.profile.output_vector()
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()
    compute_cost = None
    if include_compute and prob.compute_speed is not None:
        per_layer = np.array(comp)[:, None] / prob.compute_speed[None, :]
        compute_cost = per_layer * prob.horizon()
    mem_left = prob.mem_cap.astype(float).copy()
    comp_left = prob.comp_cap.astype(float).copy()
    assign = np.full((R, M), -1, np.int64)
    admitted = np.zeros(R, bool)
    total = 0.0
    counters = _SparseCounters() if sparse_k is not None else None
    placer = None
    if sparse_k is not None:
        placer = _SparsePlacer(spb, K, prob.profile.input_bytes, mem, comp,
                               mem_left, comp_left, compute_cost,
                               k=sparse_k, max_path_cost=max_path_cost,
                               counters=counters,
                               capacity_repair=capacity_repair)
    if placer is not None and batch_solve and R > 0:
        for r, (path, cost) in enumerate(
                _place_batch(placer, [int(s) for s in prob.sources])):
            if path is None:
                continue
            assign[r] = path
            admitted[r] = True
            total += cost
    else:
        for r in range(R):
            if placer is not None:
                path, cost = placer.place(int(prob.sources[r]))
            else:
                path, cost = _place_request(
                    spb, K, prob.profile.input_bytes, int(prob.sources[r]),
                    mem, comp, mem_left, comp_left, compute_cost,
                    capacity_repair=capacity_repair)
            if path is None or (max_path_cost is not None
                                and cost > max_path_cost):
                admitted[r] = False
                continue
            if placer is not None:
                placer.commit(path)
            else:
                for j, i in enumerate(path):
                    mem_left[i] -= mem[j]
                    comp_left[i] -= comp[j]
            assign[r] = path
            admitted[r] = True
            total += cost
    stats = None
    if counters is not None:
        stats = ResolveStats(0, R, N, True, time.perf_counter() - t0,
                             k=int(sparse_k),
                             n_dense_fallback=counters.n_dense_fallback,
                             n_escalations=counters.n_escalations,
                             pruned_fraction=counters.pruned_fraction,
                             n_batched=counters.n_batched,
                             n_jit_compiles=counters.n_jit_compiles)
    return assign, total, admitted, stats


# ---------------------------------------------------------------------------
# Public entry point with admission control
# ---------------------------------------------------------------------------

def solve_ould(prob: Problem, *, solver: Solver = "ilp",
               include_compute: bool = False, tight: bool = True,
               gamma_relaxed: bool = True, time_limit: float | None = None,
               mip_rel_gap: float = 1e-6,
               constraint_cache: dict | None = None,
               max_path_cost: float | None = None,
               sparse_k: int | None = None,
               batch_solve: bool = False,
               capacity_repair: str = "halve") -> Solution:
    """Solve an OULD / OULD-MP instance.

    Legacy entry point (kept for one release): new code goes through the
    planner registry — ``get_planner("ould-ilp" | "ould-dp" | "ould-mp")``
    — which wraps this engine with view checking and provenance.

    When the full request set is infeasible (system over capacity), requests
    are shed from the tail until feasible — the paper's 'additional incoming
    requests are rejected' behaviour (§IV-A, shared-data plateaus).  Rejected
    rows of ``assign`` carry the ``-1`` sentinel and must never be read.

    ``constraint_cache`` (a caller-owned dict) memoizes the sparse ILP
    constraint matrix across repeated solves of same-shaped instances —
    topology drift only changes the objective coefficients.

    ``sparse_k`` is the per-layer candidate budget of the ``"dp-sparse"``
    solver (None ⇒ :func:`default_sparse_k`); ignored by the other solvers.
    ``batch_solve=True`` runs the ``"dp-sparse"`` request loop through the
    batched jitted kernel (:mod:`repro.core.batch_dp`) — one dispatch per
    solve, decisions bit-identical to the sequential pass; ignored by the
    other solvers.
    """
    t0 = time.perf_counter()
    R = prob.n_requests
    if solver in ("dp", "dp-sparse"):
        k = None
        if solver == "dp-sparse":
            k = sparse_k if sparse_k is not None else default_sparse_k(prob.n_nodes)
        assign, obj, admitted, stats = _solve_dp(
            prob, include_compute=include_compute,
            max_path_cost=max_path_cost, sparse_k=k,
            batch_solve=batch_solve, capacity_repair=capacity_repair)
        n_rej = int(prob.n_requests - admitted.sum())
        status = "feasible" if n_rej == 0 else f"rejected:{n_rej}"
        return Solution(assign, obj, status, time.perf_counter() - t0,
                        admitted, solver=solver, dp_stats=stats)

    admitted = np.ones(R, bool)
    n_try = R
    while n_try >= 1:
        sub = Problem(prob.profile, prob.mem_cap, prob.comp_cap, prob.rates,
                      prob.sources[:n_try], prob.compute_speed,
                      prob.rate_unit_bytes)
        assign, obj, status = _solve_ilp_once(
            sub, include_compute=include_compute, tight=tight,
            gamma_relaxed=gamma_relaxed, time_limit=time_limit,
            mip_rel_gap=mip_rel_gap, cache=constraint_cache)
        if assign is not None:
            full = np.full((R, prob.n_layers), -1, np.int64)
            full[:n_try] = assign
            admitted[:] = False
            admitted[:n_try] = True
            st = "optimal" if n_try == R else f"rejected:{R - n_try}"
            return Solution(full, obj, st, time.perf_counter() - t0, admitted)
        n_try -= 1
    return Solution(np.full((R, prob.n_layers), -1, np.int64), float("inf"),
                    "infeasible", time.perf_counter() - t0,
                    np.zeros(R, bool))


# ---------------------------------------------------------------------------
# Incremental (warm-started) epoch re-solves
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResolveStats:
    """What one solve actually did (warm re-solve and/or sparse DP)."""

    n_kept: int            # requests whose placement survived unchanged
    n_replaced: int        # requests re-placed (path touched a changed node)
    n_changed_nodes: int   # nodes incident to a materially changed link
    cold: bool             # True when the solve fell back to a full solve
    solve_time_s: float
    n_repriced: int = -1   # transfer-cost entries re-priced (-1: full price)
    # Sparse k-candidate DP telemetry (k == 0 ⇒ the dense kernel ran).
    k: int = 0             # per-layer candidate budget of the pruned DP
    n_dense_fallback: int = 0   # requests that hit the dense last resort
    n_escalations: int = 0      # k-doubling retries across requests
    pruned_fraction: float = 0.0  # share of N² transition scans avoided
    # Batched-kernel fast path (batch_solve=True): requests whose placement
    # came certified out of the single jitted dispatch (the rest fell back
    # to the sequential ladder).
    n_batched: int = 0
    # XLA compiles triggered by this solve's jitted dispatches.  When > 0,
    # ``solve_time_s`` includes first-dispatch compile time and must not be
    # read as steady-state solve cost (DESIGN.md §9).
    n_jit_compiles: int = 0

    @property
    def cold_dispatch(self) -> bool:
        """True when the wall time above paid for at least one XLA compile."""
        return self.n_jit_compiles > 0


class IncrementalSolver:
    """Warm-started repeated OULD solves over a drifting topology.

    The swarm serving simulator re-solves placement every epoch; a cold solve
    repeats three kinds of work this class caches instead:

    1. **Constraint structure** — the ILP's sparse constraint matrix (Eq. 4–6,
       11) depends only on the instance shape and capacities, never on the
       rates, so it is memoized (``constraint_cache``) and only the objective
       vector is rebuilt per epoch (:func:`_build_objective`).
    2. **Previous epoch's assignment** — for the DP path, requests whose
       placement does not touch any *changed* node keep their paths and
       capacity reservations verbatim; only requests incident to a changed
       link (rate drift beyond ``rel_change``, connect/disconnect flips, node
       failure/rejoin) are re-placed against the residual capacity.
    3. **Request identity** — callers tag requests with stable ids so streams
       that persist across epochs inherit their placement; departed ids
       release capacity implicitly, new ids are placed fresh, and previously
       rejected ids retry admission every epoch.

    The warm re-solve reproduces the cold greedy-DP objective exactly when
    every request is re-placed (same order, same residual-capacity sequence),
    which is the invariant the tests pin down; when few links change it skips
    nearly all DP work — the ≥2× epoch-re-solve speedup the benchmark
    measures.  Capacities and the profile are fixed per instance; per-epoch
    node outages are expressed via the ``alive`` mask.
    """

    def __init__(self, profile: ModelProfile, mem_cap: np.ndarray,
                 comp_cap: np.ndarray,
                 compute_speed: np.ndarray | None = None, *,
                 solver: Solver = "dp", include_compute: bool = False,
                 rel_change: float = 0.05, price_rel_change: float = 0.0,
                 max_path_cost: float | None = None,
                 rate_unit_bytes: float = 1 / 8.0,
                 sparse_k: int | None = None, batch_solve: bool = False,
                 capacity_repair: str = "halve",
                 **ilp_kw):
        self.profile = profile
        self.mem_cap = np.asarray(mem_cap, float)
        self.comp_cap = np.asarray(comp_cap, float)
        self.compute_speed = compute_speed
        self.solver: Solver = solver
        self.include_compute = include_compute
        self.rel_change = rel_change
        # Candidate budget when solver == "dp-sparse" (None ⇒ default √N
        # rule); the warm path re-places touched requests with the SAME
        # pruned kernel + fallback ladder as the cold sparse solve.
        self.sparse_k = sparse_k
        # Epoch re-solves route the touched-request loop through the batched
        # jitted kernel (decisions unchanged; dp-sparse only).
        self.batch_solve = batch_solve
        # Over-capacity shrink rule for the repair loop ("halve" | "gentle").
        self.capacity_repair = capacity_repair
        # Entry re-pricing threshold for incremental_transfer_cost; 0.0 keeps
        # the cost matrix exact (only entries with *any* drift recomputed).
        # Must not exceed rel_change: _changed_nodes reads the incrementally
        # priced spb, so pricing staleness above the placement band would
        # silently disable the re-place trigger for sub-band drift.
        if price_rel_change > rel_change:
            raise ValueError(
                f"price_rel_change ({price_rel_change}) must be ≤ "
                f"rel_change ({rel_change}); coarser pricing would hide "
                f"link drift from the re-place trigger")
        self.price_rel_change = price_rel_change
        self.max_path_cost = max_path_cost
        self.rate_unit_bytes = rate_unit_bytes
        self.ilp_kw = ilp_kw
        self.constraint_cache: dict = {}
        self._paths: dict[int, np.ndarray] = {}   # request id → kept path
        self._spb: np.ndarray | None = None       # previous horizon-summed spb
        self._alive: np.ndarray | None = None
        self._price_rates: np.ndarray | None = None  # rates rows last priced at
        self._price_spb: np.ndarray | None = None    # matching cost matrix

    # -- problem assembly ---------------------------------------------------

    def _problem(self, rates: np.ndarray, sources: np.ndarray,
                 alive: np.ndarray | None) -> Problem:
        mem, comp = self.mem_cap, self.comp_cap
        if alive is not None and not alive.all():
            mem = np.where(alive, mem, 0.0)
            comp = np.where(alive, comp, 0.0)
            # A dead node's links are down too (ρ = 0 ⇔ disconnected), so a
            # request *sourced* at it cannot be admitted over phantom links —
            # the alive mask alone must be sufficient for callers.
            rates = rates.copy()
            if rates.ndim == 3:
                rates[:, ~alive, :] = 0.0
                rates[:, :, ~alive] = 0.0
            else:
                rates[~alive, :] = 0.0
                rates[:, ~alive] = 0.0
        return Problem(self.profile, mem, comp, rates,
                       np.asarray(sources, np.int64), self.compute_speed,
                       self.rate_unit_bytes)

    def _changed_nodes(self, spb: np.ndarray,
                       alive: np.ndarray | None) -> np.ndarray:
        """(N,) bool — nodes incident to a link whose seconds/byte moved by
        more than ``rel_change`` (covers connect/disconnect flips: the _BIG
        sentinel dwarfs any real value), or whose alive flag flipped.

        Drift is measured against the *reference* spb — the value each link
        had when its placements were last re-priced — not merely the previous
        epoch, so a link fading slowly (below the per-epoch threshold) still
        accumulates drift and eventually triggers a re-place.  This bounds
        the staleness of every kept placement to one ``rel_change`` band per
        link instead of letting it compound unboundedly."""
        n = spb.shape[0]
        if self._spb is None or self._spb.shape != spb.shape:
            return np.ones(n, bool)
        a, b = self._spb, spb
        denom = np.maximum(np.minimum(a, b), 1e-30)
        link_changed = np.abs(a - b) > self.rel_change * denom
        mask = link_changed.any(axis=0) | link_changed.any(axis=1)
        prev_alive = self._alive if self._alive is not None else np.ones(n, bool)
        cur_alive = alive if alive is not None else np.ones(n, bool)
        return mask | (prev_alive != cur_alive)

    def _remember(self, spb: np.ndarray, alive: np.ndarray | None,
                  request_ids, assign: np.ndarray, admitted: np.ndarray,
                  changed: np.ndarray | None = None) -> None:
        if changed is None or self._spb is None or self._spb.shape != spb.shape:
            self._spb = spb.copy()
        else:
            # Advance the reference only for links incident to a changed node
            # (their placements were just re-priced); untouched links keep
            # their old reference so slow drift accumulates.
            touched = changed[:, None] | changed[None, :]
            self._spb = np.where(touched, spb, self._spb)
        self._alive = (np.asarray(alive, bool).copy()
                       if alive is not None else np.ones(spb.shape[0], bool))
        self._paths = {int(rid): assign[r].copy()
                       for r, rid in enumerate(request_ids) if admitted[r]}

    def _priced_spb(self, prob: Problem) -> tuple[np.ndarray, int]:
        """Transfer-cost matrix via changed-entry re-pricing when a reference
        exists (ROADMAP: incremental ``transfer_cost``).  Returns the matrix
        and how many entries were re-priced (-1 ⇒ full pricing)."""
        rates = prob.rates
        if (self._price_spb is None or self._price_rates is None
                or self._price_rates.shape != rates.shape):
            spb = prob.transfer_cost()
            self._price_rates = np.asarray(rates, float).copy()
            self._price_spb = spb.copy()
            return spb, -1
        spb, repriced = incremental_transfer_cost(
            rates, self._price_rates, self._price_spb,
            rel_change=self.price_rel_change,
            rate_unit_bytes=prob.rate_unit_bytes)
        n = int(repriced.sum())
        if n:
            # Advance the pricing reference only for re-priced entries;
            # entries drifting below the threshold keep their old reference
            # so slow drift accumulates toward a re-price, never compounds.
            self._price_rates[..., repriced] = rates[..., repriced]
            self._price_spb = spb.copy()
        return spb, n

    # -- entry points -------------------------------------------------------

    def solve(self, rates: np.ndarray, sources: np.ndarray,
              request_ids=None,
              alive: np.ndarray | None = None) -> tuple[Solution, ResolveStats]:
        """Cold solve (still reusing the ILP constraint cache); primes the
        warm state for subsequent :meth:`resolve` calls."""
        t0 = time.perf_counter()
        prob = self._problem(rates, sources, alive)
        if request_ids is None:
            request_ids = list(range(prob.n_requests))
        sol = solve_ould(prob, solver=self.solver,
                         include_compute=self.include_compute,
                         constraint_cache=self.constraint_cache,
                         max_path_cost=self.max_path_cost,
                         sparse_k=self.sparse_k,
                         batch_solve=self.batch_solve,
                         capacity_repair=self.capacity_repair,
                         **self.ilp_kw)
        spb, n_repriced = self._priced_spb(prob)
        self._remember(spb, alive, request_ids, sol.assign, sol.admitted)
        dt = time.perf_counter() - t0
        ds = sol.dp_stats
        return sol, ResolveStats(
            0, prob.n_requests, prob.n_nodes, True, dt, n_repriced,
            k=ds.k if ds else 0,
            n_dense_fallback=ds.n_dense_fallback if ds else 0,
            n_escalations=ds.n_escalations if ds else 0,
            pruned_fraction=ds.pruned_fraction if ds else 0.0,
            n_batched=ds.n_batched if ds else 0,
            n_jit_compiles=ds.n_jit_compiles if ds else 0)

    def resolve(self, rates: np.ndarray, sources: np.ndarray,
                request_ids=None,
                alive: np.ndarray | None = None) -> tuple[Solution, ResolveStats]:
        """Warm epoch re-solve: keep unaffected placements, re-place the rest.

        Falls back to a (constraint-cached) cold solve on the first call and
        in ILP mode, where scipy's MILP cannot consume an incumbent.
        """
        t0 = time.perf_counter()
        prob = self._problem(rates, sources, alive)
        R, M = prob.n_requests, prob.n_layers
        if request_ids is None:
            request_ids = list(range(R))
        if self.solver not in ("dp", "dp-sparse") or self._spb is None:
            return self.solve(rates, sources, request_ids, alive)

        spb, n_repriced = self._priced_spb(prob)
        changed = self._changed_nodes(spb, alive)
        # A departed stream frees its nodes' reservations — a capacity event
        # as real as a link change: placements (and sources) on those nodes
        # get a chance to re-pack onto the freed capacity.
        live_ids = {int(rid) for rid in request_ids}
        for rid, prev in self._paths.items():
            if rid not in live_ids:
                changed[prev] = True
        K = self.profile.output_vector()
        Ks = self.profile.input_bytes
        mem = self.profile.memory_vector()
        comp = self.profile.compute_vector()
        compute_cost = None
        if self.include_compute and self.compute_speed is not None:
            per_layer = np.array(comp)[:, None] / self.compute_speed[None, :]
            compute_cost = per_layer * prob.horizon()

        mem_left = prob.mem_cap.astype(float).copy()
        comp_left = prob.comp_cap.astype(float).copy()
        assign = np.full((R, M), -1, np.int64)
        admitted = np.zeros(R, bool)
        todo: list[int] = []
        for r, rid in enumerate(request_ids):
            prev = self._paths.get(int(rid))
            src = int(prob.sources[r])
            if prev is not None and not changed[prev].any() and not changed[src]:
                for j, i in enumerate(prev):          # keep: reserve capacity
                    mem_left[i] -= mem[j]
                    comp_left[i] -= comp[j]
                assign[r] = prev
                admitted[r] = True
            else:
                todo.append(r)
        n_kept = R - len(todo)
        sparse = self.solver == "dp-sparse"
        counters = _SparseCounters() if sparse else None
        k = (self.sparse_k if self.sparse_k is not None
             else default_sparse_k(prob.n_nodes)) if sparse else 0
        placer = None
        if sparse:
            placer = _SparsePlacer(spb, K, Ks, mem, comp, mem_left,
                                   comp_left, compute_cost, k=k,
                                   max_path_cost=self.max_path_cost,
                                   counters=counters,
                                   capacity_repair=self.capacity_repair)
        if placer is not None and self.batch_solve and todo:
            placed = _place_batch(placer,
                                  [int(prob.sources[r]) for r in todo])
            for r, (path, cost) in zip(todo, placed):
                if path is None:
                    continue
                assign[r] = path
                admitted[r] = True
        else:
            for r in todo:
                if placer is not None:
                    path, cost = placer.place(int(prob.sources[r]))
                else:
                    path, cost = _place_request(spb, K, Ks,
                                                int(prob.sources[r]),
                                                mem, comp, mem_left,
                                                comp_left, compute_cost,
                                                capacity_repair=self.capacity_repair)
                if path is None or (self.max_path_cost is not None
                                    and cost > self.max_path_cost):
                    continue
                if placer is not None:
                    placer.commit(path)
                else:
                    for j, i in enumerate(path):
                        mem_left[i] -= mem[j]
                        comp_left[i] -= comp[j]
                assign[r] = path
                admitted[r] = True
        # Objective re-priced for EVERY admitted request — kept paths are not
        # assumed to still cost what they used to.  The spb is exact at
        # price_rel_change=0 (the default); otherwise entries may lag the
        # true rates by at most one price band (≤ rel_change by contract).
        total = sum(_path_cost(spb, K, Ks, int(prob.sources[r]), assign[r],
                               compute_cost)
                    for r in range(R) if admitted[r])
        self._remember(spb, alive, request_ids, assign, admitted, changed)
        dt = time.perf_counter() - t0
        n_rej = int(R - admitted.sum())
        status = "feasible" if n_rej == 0 else f"rejected:{n_rej}"
        sol = Solution(assign, float(total), status, dt, admitted,
                       solver="dp-sparse-warm" if sparse else "dp-warm")
        return sol, ResolveStats(
            n_kept, len(todo), int(changed.sum()), False, dt, n_repriced,
            k=k,
            n_dense_fallback=counters.n_dense_fallback if counters else 0,
            n_escalations=counters.n_escalations if counters else 0,
            pruned_fraction=counters.pruned_fraction if counters else 0.0,
            n_batched=counters.n_batched if counters else 0,
            n_jit_compiles=counters.n_jit_compiles if counters else 0)
