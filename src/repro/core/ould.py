"""OULD — Optimal UAV-based Layer Distribution (paper §III-B, Eq. 3–13)
and OULD-MP — with mobility prediction (paper §III-C, Eq. 14–15).

Decision variables
------------------
``α_{r,i,j} ∈ {0,1}``  — node i executes layer j of request r (Eq. 2).
``γ_{r,i,k,j} ∈ {0,1}`` — node i runs layer j of r AND node k runs layer j+1
(Eq. 9/10), introduced to linearize the bilinear objective via the big-M
rules (Eq. 11):

    γ ≤ α_{r,i,j},   γ ≤ α_{r,k,j+1},   γ ≥ α_{r,i,j} + α_{r,k,j+1} − 1.

Objective (Eq. 12 + 13):  min Σ_r Σ_{i≠k} Σ_{j<M} γ_{r,i,k,j}·K_j/ρ_{i,k} + t_s
with t_s the source-image transfer.  Because Σ_i α_{r,i,1} = 1 (Eq. 6), the
source term is *already linear*: t_s = Σ_{k≠src(r)} α_{r,k,1}·K_s/ρ_{src,k}.

Constraints: per-node memory (Eq. 4) and compute (Eq. 5) occupancy caps, and
exactly-one placement per (request, layer) (Eq. 6); binariness (Eq. 7).

Solvers
-------
* ``solver="ilp"``   — paper-faithful ILP via HiGHS (`scipy.optimize.milp`).
  ``gamma_relaxed=True`` (default) declares γ continuous in [0,1]: with the
  big-M inequalities and binary α, γ* = α_i·α_k at every vertex, so the optimum
  is unchanged while the branch-and-bound tree only explores α.  This is an
  exactness-preserving speedup (validated against the all-binary mode in
  tests).  ``tight=True`` keeps the two ≤ inequalities the paper writes; they
  are redundant for a non-negative objective but retained by default for
  faithfulness.
* ``solver="dp"``    — exact per-request shortest-path DP through the N×M
  lattice when capacity constraints are slack; with contention it becomes a
  sequential greedy-DP (requests placed one at a time, capacities decremented)
  — our large-instance fallback, also the warm-start generator.

OULD-MP is the same formulation with rate coefficients summed over the
predicted horizon: cost(i,k) uses Σ_t 1/ρ_{i,k}(t) (Eq. 14).  A pair that is
predicted to *disconnect* (ρ=0 at any t) gets an infinite coefficient, which
is exactly the paper's argument for why MP avoids mid-mission outages.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Literal

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from .profiles import ModelProfile

Solver = Literal["ilp", "dp"]

_BIG = 1e12  # stand-in for an unreachable (disconnected) pair


@dataclasses.dataclass(frozen=True)
class Problem:
    """One OULD instance (a set of concurrent requests on a topology)."""

    profile: ModelProfile
    mem_cap: np.ndarray          # (N,) m̄_i, bytes
    comp_cap: np.ndarray         # (N,) c̄_i, FLOPs budget per decision period
    rates: np.ndarray            # (N,N) ρ bits/s — or (T,N,N) for OULD-MP
    sources: np.ndarray          # (R,) source node of each request (μ_{i,r})
    compute_speed: np.ndarray | None = None  # (N,) FLOPs/s for latency eval
    rate_unit_bytes: float = 1 / 8.0  # bits/s rates → bytes = K·8/ρ

    @property
    def n_nodes(self) -> int:
        return int(self.mem_cap.shape[0])

    @property
    def n_requests(self) -> int:
        return int(self.sources.shape[0])

    @property
    def n_layers(self) -> int:
        return self.profile.num_layers

    def horizon(self) -> int:
        return 1 if self.rates.ndim == 2 else int(self.rates.shape[0])

    def transfer_cost(self) -> np.ndarray:
        """(N,N) seconds per byte between node pairs, summed over the horizon
        (Eq. 14 sums transfer latency over t ∈ {1..T})."""
        rates = self.rates[None] if self.rates.ndim == 2 else self.rates
        secs_per_byte = np.zeros(rates.shape[1:])
        for t in range(rates.shape[0]):
            r = rates[t]
            with np.errstate(divide="ignore"):
                spb = np.where(r > 0, (1.0 / self.rate_unit_bytes) / np.maximum(r, 1e-30), _BIG)
            np.fill_diagonal(spb, 0.0)  # same node: no transfer
            secs_per_byte = secs_per_byte + spb
        return secs_per_byte


@dataclasses.dataclass
class Solution:
    assign: np.ndarray           # (R, M) node index per (request, layer)
    objective: float             # communication latency (paper objective)
    status: str                  # "optimal" | "feasible" | "rejected:<n>"
    solve_time_s: float
    admitted: np.ndarray         # (R,) bool — False = request rejected
    solver: str = "ilp"

    @property
    def n_admitted(self) -> int:
        return int(self.admitted.sum())


# ---------------------------------------------------------------------------
# ILP construction
# ---------------------------------------------------------------------------

class _Index:
    """Flat variable indexing: α block then γ block."""

    def __init__(self, R: int, N: int, M: int):
        self.R, self.N, self.M = R, N, M
        self.n_alpha = R * N * M
        # γ over r, j ∈ {1..M-1}, ordered pairs i≠k
        self.pairs = [(i, k) for i in range(N) for k in range(N) if i != k]
        self.n_gamma = R * (M - 1) * len(self.pairs)
        self.n_vars = self.n_alpha + self.n_gamma
        self._pair_id = {p: q for q, p in enumerate(self.pairs)}

    def a(self, r: int, i: int, j: int) -> int:
        return (r * self.N + i) * self.M + j

    def g(self, r: int, j: int, i: int, k: int) -> int:
        q = self._pair_id[(i, k)]
        return self.n_alpha + (r * (self.M - 1) + j) * len(self.pairs) + q


def _build_ilp(prob: Problem, *, include_compute: bool, tight: bool):
    R, N, M = prob.n_requests, prob.n_nodes, prob.n_layers
    idx = _Index(R, N, M)
    spb = prob.transfer_cost()          # (N,N) seconds/byte over horizon
    K = prob.profile.output_vector()    # K_j bytes
    Ks = prob.profile.input_bytes
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()

    c = np.zeros(idx.n_vars)
    # Source term t_s (Eq. 13): linear in α_{r,k,1}.
    for r in range(R):
        src = int(prob.sources[r])
        for k in range(N):
            if k != src:
                c[idx.a(r, k, 0)] += Ks * spb[src, k]
    # Inter-layer transfers (Eq. 12): γ_{r,i,k,j} · K_j / ρ_{i,k}.
    for r in range(R):
        for j in range(M - 1):
            for (i, k) in idx.pairs:
                c[idx.g(r, j, i, k)] += K[j] * spb[i, k]
    if include_compute and prob.compute_speed is not None:
        # Heterogeneous-speed extension (linear): Σ α_{r,i,j}·c_j/speed_i.
        for r in range(R):
            for i in range(N):
                for j in range(M):
                    c[idx.a(r, i, j)] += comp[j] / prob.compute_speed[i]

    rows, cols, vals, lo, hi = [], [], [], [], []
    row = 0

    def add_row(entries, lo_v, hi_v):
        nonlocal row
        for col, v in entries:
            rows.append(row)
            cols.append(col)
            vals.append(v)
        lo.append(lo_v)
        hi.append(hi_v)
        row += 1

    # Eq. 4 memory / Eq. 5 compute capacity per node.
    for i in range(N):
        add_row([(idx.a(r, i, j), mem[j]) for r in range(R) for j in range(M)],
                -np.inf, float(prob.mem_cap[i]))
    for i in range(N):
        add_row([(idx.a(r, i, j), comp[j]) for r in range(R) for j in range(M)],
                -np.inf, float(prob.comp_cap[i]))
    # Eq. 6 exactly-one per (r, j).
    for r in range(R):
        for j in range(M):
            add_row([(idx.a(r, i, j), 1.0) for i in range(N)], 1.0, 1.0)
    # Eq. 11 big-M linking.
    for r in range(R):
        for j in range(M - 1):
            for (i, k) in idx.pairs:
                g = idx.g(r, j, i, k)
                ai, ak = idx.a(r, i, j), idx.a(r, k, j + 1)
                add_row([(g, 1.0), (ai, -1.0), (ak, -1.0)], -1.0, np.inf)
                if tight:
                    add_row([(g, 1.0), (ai, -1.0)], -np.inf, 0.0)
                    add_row([(g, 1.0), (ak, -1.0)], -np.inf, 0.0)

    A = sp.csc_matrix((vals, (rows, cols)), shape=(row, idx.n_vars))
    return idx, c, LinearConstraint(A, np.array(lo), np.array(hi))


def _solve_ilp_once(prob: Problem, *, include_compute: bool, tight: bool,
                    gamma_relaxed: bool, time_limit: float | None,
                    mip_rel_gap: float) -> tuple[np.ndarray | None, float, str]:
    R, N, M = prob.n_requests, prob.n_nodes, prob.n_layers
    idx, c, constraints = _build_ilp(prob, include_compute=include_compute,
                                     tight=tight)
    # Normalize the objective so HiGHS tolerances (~1e-7 absolute) are far
    # below the cost scale — latencies can be microseconds on fast links.
    finite = np.abs(c[np.isfinite(c) & (np.abs(c) > 0) & (np.abs(c) < _BIG)])
    scale = 1.0 / finite.max() if finite.size else 1.0
    c = np.minimum(c * scale, 1e9)  # disconnected pairs stay priced out
    integrality = np.zeros(idx.n_vars)
    integrality[: idx.n_alpha] = 1
    if not gamma_relaxed:
        integrality[:] = 1
    opts: dict = {"mip_rel_gap": mip_rel_gap}
    if time_limit is not None:
        opts["time_limit"] = time_limit
    res = milp(c, constraints=constraints, integrality=integrality,
               bounds=Bounds(0.0, 1.0), options=opts)
    # status 0 = optimal; 1 = hit time/iteration limit (accept incumbent)
    if res.status not in (0, 1) or res.x is None:
        return None, float("inf"), "infeasible" if res.status == 2 else f"status{res.status}"
    alpha = res.x[: idx.n_alpha].reshape(R, N, M)
    assign = alpha.argmax(axis=1).astype(np.int64)  # (R, M)
    return (assign, float(res.fun) / scale,
            "optimal" if res.status == 0 else "feasible")


# ---------------------------------------------------------------------------
# Exact per-request DP (lattice shortest path) + sequential greedy-DP
# ---------------------------------------------------------------------------

def _dp_single_request(spb: np.ndarray, K: list[float], Ks: float, src: int,
                       mem: list[float], comp: list[float],
                       mem_left: np.ndarray, comp_left: np.ndarray,
                       compute_cost: np.ndarray | None) -> tuple[np.ndarray | None, float]:
    """Shortest path through the (layer, node) lattice for one request.

    State cost(j, i): min latency to have layer j's output resident on node i.
    Edge (j-1,k) → (j,i): K_{j-1}·spb[k,i] (0 if k == i).  Feasibility of
    putting layer j on node i uses *remaining* capacity — exact for a single
    request when the node never needs to split a single layer.
    """
    N, M = spb.shape[0], len(K)
    INF = float("inf")
    feas = np.zeros((M, N), bool)
    for j in range(M):
        feas[j] = (mem_left >= mem[j]) & (comp_left >= comp[j])
    cost = np.full((M, N), INF)
    back = np.full((M, N), -1, np.int64)
    for i in range(N):
        if feas[0, i]:
            cost[0, i] = 0.0 if i == src else Ks * spb[src, i]
            if compute_cost is not None:
                cost[0, i] += compute_cost[0, i]
    for j in range(1, M):
        # NOTE: single-request DP treats per-layer feasibility independently;
        # when one node hosts several layers of the SAME request the combined
        # load is checked post-hoc by the caller and repaired greedily.
        prev = cost[j - 1]
        step = prev[:, None] + np.array(K[j - 1]) * spb  # (k→i)
        if compute_cost is not None:
            step = step + compute_cost[j][None, :]
        step[:, ~feas[j]] = INF
        back[j] = step.argmin(axis=0)
        cost[j] = step[back[j], np.arange(N)]
    end = int(np.argmin(cost[-1]))
    if not np.isfinite(cost[-1, end]):
        return None, INF
    path = np.zeros(M, np.int64)
    path[-1] = end
    for j in range(M - 1, 0, -1):
        path[j - 1] = back[j, path[j]]
    return path, float(cost[-1, end])


def _repair_capacity(path: np.ndarray, mem: list[float], comp: list[float],
                     mem_left: np.ndarray, comp_left: np.ndarray) -> bool:
    """Check a DP path against *joint* per-node load; True if it fits."""
    N = mem_left.shape[0]
    m_use = np.zeros(N)
    c_use = np.zeros(N)
    for j, i in enumerate(path):
        m_use[i] += mem[j]
        c_use[i] += comp[j]
    return bool(np.all(m_use <= mem_left + 1e-9) and np.all(c_use <= comp_left + 1e-9))


def _solve_dp(prob: Problem, *, include_compute: bool) -> tuple[np.ndarray, float, np.ndarray]:
    """Sequential greedy-DP: requests placed one at a time (exact per request,
    greedy across requests).  Returns (assign, total_comm_latency, admitted)."""
    R, N, M = prob.n_requests, prob.n_nodes, prob.n_layers
    spb = prob.transfer_cost()
    K = prob.profile.output_vector()
    mem = prob.profile.memory_vector()
    comp = prob.profile.compute_vector()
    compute_cost = None
    if include_compute and prob.compute_speed is not None:
        per_layer = np.array(comp)[:, None] / prob.compute_speed[None, :]
        compute_cost = per_layer * prob.horizon()
    mem_left = prob.mem_cap.astype(float).copy()
    comp_left = prob.comp_cap.astype(float).copy()
    assign = np.zeros((R, M), np.int64)
    admitted = np.zeros(R, bool)
    total = 0.0
    for r in range(R):
        path, cost = _dp_single_request(
            spb, K, prob.profile.input_bytes, int(prob.sources[r]),
            mem, comp, mem_left, comp_left, compute_cost)
        # Repair loop: the lattice DP checks per-layer feasibility, not the
        # joint within-request load.  Iteratively shrink the advertised
        # memory AND compute of the most-overloaded node and re-plan —
        # forces the DP to spread until the joint check passes.
        mem_adv = mem_left.copy()
        comp_adv = comp_left.copy()
        for _ in range(4 * N):
            if path is None or _repair_capacity(path, mem, comp, mem_left,
                                                comp_left):
                break
            m_load = np.zeros(N)
            c_load = np.zeros(N)
            for j, i in enumerate(path):
                m_load[i] += mem[j]
                c_load[i] += comp[j]
            m_over = m_load - mem_left
            c_over = c_load - comp_left
            if m_over.max() >= c_over.max() / max(comp_left.max(), 1e-9) * \
                    max(mem_left.max(), 1e-9):
                busy = int(m_over.argmax())
                mem_adv[busy] = max(mem_adv[busy] / 2.0, 0.0)
                if mem_adv[busy] < min((m for m in mem if m > 0), default=0):
                    mem_adv[busy] = 0.0
            else:
                busy = int(c_over.argmax())
                comp_adv[busy] = max(comp_adv[busy] / 2.0, 0.0)
                if comp_adv[busy] < min((c for c in comp if c > 0), default=0):
                    comp_adv[busy] = 0.0
            path, cost = _dp_single_request(
                spb, K, prob.profile.input_bytes, int(prob.sources[r]),
                mem, comp, mem_adv, comp_adv, compute_cost)
        if path is None or not _repair_capacity(path, mem, comp, mem_left, comp_left):
            admitted[r] = False
            continue
        for j, i in enumerate(path):
            mem_left[i] -= mem[j]
            comp_left[i] -= comp[j]
        assign[r] = path
        admitted[r] = True
        total += cost
    return assign, total, admitted


# ---------------------------------------------------------------------------
# Public entry point with admission control
# ---------------------------------------------------------------------------

def solve_ould(prob: Problem, *, solver: Solver = "ilp",
               include_compute: bool = False, tight: bool = True,
               gamma_relaxed: bool = True, time_limit: float | None = None,
               mip_rel_gap: float = 1e-6) -> Solution:
    """Solve an OULD / OULD-MP instance.

    When the full request set is infeasible (system over capacity), requests
    are shed from the tail until feasible — the paper's 'additional incoming
    requests are rejected' behaviour (§IV-A, shared-data plateaus).
    """
    t0 = time.perf_counter()
    R = prob.n_requests
    if solver == "dp":
        assign, obj, admitted = _solve_dp(prob, include_compute=include_compute)
        return Solution(assign, obj, "feasible", time.perf_counter() - t0,
                        admitted, solver="dp")

    admitted = np.ones(R, bool)
    n_try = R
    while n_try >= 1:
        sub = Problem(prob.profile, prob.mem_cap, prob.comp_cap, prob.rates,
                      prob.sources[:n_try], prob.compute_speed,
                      prob.rate_unit_bytes)
        assign, obj, status = _solve_ilp_once(
            sub, include_compute=include_compute, tight=tight,
            gamma_relaxed=gamma_relaxed, time_limit=time_limit,
            mip_rel_gap=mip_rel_gap)
        if assign is not None:
            full = np.zeros((R, prob.n_layers), np.int64)
            full[:n_try] = assign
            admitted[:] = False
            admitted[:n_try] = True
            st = "optimal" if n_try == R else f"rejected:{R - n_try}"
            return Solution(full, obj, st, time.perf_counter() - t0, admitted)
        n_try -= 1
    return Solution(np.zeros((R, prob.n_layers), np.int64), float("inf"),
                    "infeasible", time.perf_counter() - t0,
                    np.zeros(R, bool))
