"""Placement → execution bridge.

OULD emits ``assign[r, j] = node``.  For a sequential model that path visits
a sequence of nodes; grouping consecutive layers hosted on the same node
yields *pipeline stages* — the unit the JAX runtime executes (shard_map
pipeline in ``parallel/pipeline.py``) and the unit the TPU placement uses
when OULD runs over an ICI/DCN topology (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .ould import Problem, solve_ould
from .profiles import ModelProfile
from .radio import TpuLinkModel


@dataclasses.dataclass(frozen=True)
class Stage:
    node: int
    layer_start: int  # inclusive
    layer_end: int    # exclusive


def to_stages(path: np.ndarray) -> list[Stage]:
    """Group a per-layer node path into contiguous stages."""
    stages: list[Stage] = []
    start = 0
    for j in range(1, len(path) + 1):
        if j == len(path) or path[j] != path[start]:
            stages.append(Stage(int(path[start]), start, j))
            start = j
    return stages


def stage_boundaries(path: np.ndarray) -> list[int]:
    """Layer indices where the activation crosses a link (cut points)."""
    return [j + 1 for j in range(len(path) - 1) if path[j + 1] != path[j]]


def balanced_stages(profile: ModelProfile, n_stages: int) -> list[Stage]:
    """FLOPs-balanced contiguous split — the *static* baseline the paper's
    related work uses ([32]-style offline partitioning); also the PP default
    when OULD is disabled."""
    flops = np.array(profile.compute_vector())
    stages: list[Stage] = []
    start, acc, node = 0, 0.0, 0
    remaining = float(flops.sum())
    for j, f in enumerate(flops):
        acc += f
        remaining_layers = len(flops) - (j + 1)
        remaining_stages = n_stages - (node + 1)
        target = (remaining) / (n_stages - node)  # adaptive re-balance
        nxt = flops[j + 1] if j + 1 < len(flops) else 0.0
        close = (remaining_stages > 0
                 and (acc + nxt / 2 >= target or remaining_layers <= remaining_stages))
        if close:
            stages.append(Stage(node, start, j + 1))
            remaining -= acc
            start, acc, node = j + 1, 0.0, node + 1
            if node == n_stages - 1:
                break
    stages.append(Stage(node, start, len(flops)))
    return [s for s in stages if s.layer_end > s.layer_start]


def ould_pipeline_stages(profile: ModelProfile, *, n_groups: int,
                         hbm_bytes_per_group: float,
                         flops_cap_per_group: float,
                         link: TpuLinkModel | None = None,
                         solver: str = "ilp") -> list[Stage]:
    """Run OULD on a TPU topology to derive pipeline stage placement.

    Each 'node' is a chip-group laid out along one torus row; the rate matrix
    comes from :class:`TpuLinkModel`.  This is the paper's technique applied
    as the framework's PP auto-placement (first-class feature).
    """
    link = link or TpuLinkModel()
    coords = np.stack([np.arange(n_groups) % link.torus[0],
                       np.arange(n_groups) // link.torus[0]], -1)
    pods = np.zeros(n_groups, np.int64)
    rho_bytes = link.rate_matrix(coords, pods)           # bytes/s
    prob = Problem(
        profile=profile,
        mem_cap=np.full(n_groups, hbm_bytes_per_group),
        comp_cap=np.full(n_groups, flops_cap_per_group),
        rates=rho_bytes * 8.0,                            # Problem wants bits/s
        sources=np.zeros(1, np.int64),
    )
    sol = solve_ould(prob, solver=solver)  # type: ignore[arg-type]
    if not sol.admitted[0]:
        raise ValueError(
            "OULD found no feasible pipeline placement: "
            f"{profile.name} needs more than {n_groups} groups × "
            f"{hbm_bytes_per_group:.2e} B")
    return to_stages(sol.assign[0])
