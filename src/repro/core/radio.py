"""Air-to-air link model — paper Eq. (1) and §III-C link-quality semantics.

``ρ_{i,k} = B_i · log2(1 + Γ_{i,k})`` where Γ is the average SINR of the U2U
link.  Received power follows the path-loss law ``P_rx ∝ P_tx · d^{-α}``
(§III-C); interference sums the received power of concurrent transmitters
(the paper's latency curves attribute the density penalty to exactly this
term, citing [38]).  Disconnection: beyond ``max_range`` the SINR is treated
as 0 so ρ = B·log2(1) = 0, verbatim the paper's limit argument.

The same ``RateModel`` protocol also has a TPU instantiation
(:class:`TpuLinkModel`) used when OULD drives pipeline placement on a pod —
contention-free per-direction links, rate = per-link ICI/DCN bandwidth divided
by hop distance on the torus.  See DESIGN.md §2 for the mapping.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RadioParams:
    bandwidth_hz: float = 20e6       # B_i = 20 MHz (paper §IV)
    tx_power_w: float = 0.1          # typical UAV Wi-Fi class transmitter
    noise_w: float = 1e-13           # thermal noise floor over 20 MHz
    path_loss_exp: float = 2.7       # α, LoS air-to-air (between 2 and 3)
    ref_gain: float = 1e-4           # channel gain at 1 m
    max_range_m: float = 300.0       # beyond this, link disconnected (ρ = 0)
    interference_frac: float = 0.1   # duty-cycle share of concurrent tx heard


def received_power(d: np.ndarray, p: RadioParams) -> np.ndarray:
    """P_rx ∝ d^{-α} with a reference gain; clamped below 1 m."""
    d = np.maximum(d, 1.0)
    return p.tx_power_w * p.ref_gain * d ** (-p.path_loss_exp)


def sinr_matrix(positions: np.ndarray, p: RadioParams) -> np.ndarray:
    """Γ_{i,k} for all pairs.

    positions: (N, 3) UAV coordinates.  Interference at receiver k sums the
    received power of all nodes other than {i, k}, scaled by the duty-cycle
    fraction (concurrent transmitters), matching the density penalty the
    paper observes for N=15 swarms.
    """
    n = positions.shape[0]
    diff = positions[:, None, :] - positions[None, :, :]
    dist = np.linalg.norm(diff, axis=-1)
    prx = received_power(dist, p)  # prx[i, k]: power of i heard at k
    np.fill_diagonal(prx, 0.0)
    total_at_k = prx.sum(axis=0)  # (N,) all power arriving at k
    sinr = np.zeros((n, n))
    for i in range(n):
        interference = (total_at_k - prx[i]) * p.interference_frac
        sinr[i] = prx[i] / (p.noise_w + interference)
    np.fill_diagonal(sinr, 0.0)
    sinr[dist > p.max_range_m] = 0.0
    return sinr


def rate_matrix(positions: np.ndarray, p: RadioParams | None = None) -> np.ndarray:
    """ρ_{i,k} = B·log2(1 + Γ_{i,k}) in bits/s — paper Eq. (1)."""
    p = p or RadioParams()
    gamma = sinr_matrix(positions, p)
    rho = p.bandwidth_hz * np.log2(1.0 + gamma)
    np.fill_diagonal(rho, np.inf)  # self-transfer is free (same node)
    return rho


# ---------------------------------------------------------------------------
# TPU instantiation of the same link abstraction (DESIGN.md §2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TpuLinkModel:
    """Hop-count rate model on a 2D ICI torus with a slower pod-to-pod DCN."""

    ici_bytes_per_s: float = 50e9    # per link per direction (spec constant)
    dcn_bytes_per_s: float = 12.5e9  # inter-pod
    torus: tuple[int, int] = (16, 16)

    def rate_matrix(self, coords: np.ndarray, pods: np.ndarray) -> np.ndarray:
        """coords: (N, 2) torus coordinates; pods: (N,) pod index.

        Rate in *bytes/s*: ICI bandwidth divided by torus hop distance when in
        the same pod, DCN bandwidth across pods.  No interference term —
        point-to-point ICI links are contention-free per direction.
        """
        tx, ty = self.torus
        dx = np.abs(coords[:, None, 0] - coords[None, :, 0])
        dy = np.abs(coords[:, None, 1] - coords[None, :, 1])
        hops = np.minimum(dx, tx - dx) + np.minimum(dy, ty - dy)
        hops = np.maximum(hops, 1)
        rho = self.ici_bytes_per_s / hops
        cross = pods[:, None] != pods[None, :]
        rho = np.where(cross, self.dcn_bytes_per_s, rho)
        np.fill_diagonal(rho, np.inf)
        return rho
