"""Reference-Point-Group (RPG) mobility — paper §III-C, citing [40].

A group leader follows a round-trip path between an initial and a final
point chosen to cover the target area; member UAVs are randomly placed
around the leader's reference point and follow the group's motion trend
with a small liberty radius.  Positions are recorded every time step;
OULD-MP consumes the *predicted* positions for t ∈ {1..T} and the induced
rate matrices ρ(t).

Deterministic given a seed — prediction in this model is exact replay of
the planned trajectory (the paper assumes planned paths are inputs).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .radio import RadioParams, rate_matrix


@dataclasses.dataclass(frozen=True)
class RPGParams:
    n_uavs: int = 10
    area_m: float = 100.0          # square side (paper: 100 and 500)
    altitude_m: float = 50.0       # fixed H (paper §III-A)
    leader_speed_mps: float = 5.0
    member_radius_m: float = 25.0  # liberty radius around reference point
    member_jitter_mps: float = 1.0 # per-step deviation inside the group
    step_s: float = 1.0            # T time-step duration
    homogeneous: bool = False      # if True, relative distances frozen (Fig. 2a)


class RPGMobility:
    """Generates (T, N, 3) positions; supports homogeneous (Fig. 2a) and
    non-homogeneous (Fig. 2b) group motion."""

    def __init__(self, params: RPGParams, seed: int = 0):
        self.p = params
        rng = np.random.default_rng(seed)
        r = rng.uniform(0, params.member_radius_m, params.n_uavs)
        theta = rng.uniform(0, 2 * np.pi, params.n_uavs)
        self._offsets = np.stack([r * np.cos(theta), r * np.sin(theta)], -1)
        self._rng = rng
        # Leader round-trip: corner-to-corner sweep covering the area.
        self._start = np.array([params.member_radius_m, params.member_radius_m])
        self._end = np.array([params.area_m - params.member_radius_m,
                              params.area_m - params.member_radius_m])

    def _leader_at(self, t: float) -> np.ndarray:
        span = np.linalg.norm(self._end - self._start)
        period = 2.0 * span / self.p.leader_speed_mps
        phase = (t * self.p.step_s) % period
        frac = phase / period * 2.0
        if frac > 1.0:
            frac = 2.0 - frac  # return leg of the round trip
        return self._start + frac * (self._end - self._start)

    def positions(self, num_steps: int, seed: int | None = None) -> np.ndarray:
        """(T, N, 3) planned positions for t = 0..T-1."""
        rng = np.random.default_rng(seed) if seed is not None else self._rng
        out = np.zeros((num_steps, self.p.n_uavs, 3))
        offsets = self._offsets.copy()
        for t in range(num_steps):
            leader = self._leader_at(t)
            if not self.p.homogeneous:
                drift = rng.normal(0.0, self.p.member_jitter_mps * self.p.step_s,
                                   offsets.shape)
                offsets = offsets + drift
                # members stay within the group liberty radius
                norm = np.linalg.norm(offsets, axis=-1, keepdims=True)
                scale = np.minimum(1.0, self.p.member_radius_m / np.maximum(norm, 1e-9))
                offsets = offsets * scale
            out[t, :, :2] = leader + offsets
            out[t, :, 2] = self.p.altitude_m
        return out

    def predicted_rates(self, num_steps: int, radio: RadioParams | None = None,
                        seed: int | None = None) -> np.ndarray:
        """(T, N, N) ρ_{i,k}(t) for OULD-MP (Eq. 14) — bits/s."""
        pos = self.positions(num_steps, seed=seed)
        return np.stack([rate_matrix(pos[t], radio) for t in range(pos.shape[0])])


class MultiGroupMobility:
    """Several RPG groups sweeping the area on independent leader paths.

    The single-group model keeps every pair within twice the liberty radius,
    so links never cross ``max_range`` and mobility alone cannot disconnect
    them.  Real surveillance swarms (§III-C, citing [40]) fly as *multiple*
    reference-point groups; inter-group distances then swing across the whole
    area and links predictably fade in and out of range — exactly the
    disconnection dynamics OULD-MP's horizon objective prices out (Fig. 13).

    Groups share the planned-trajectory determinism of :class:`RPGMobility`;
    group g's leader sweep is phase-shifted and direction-alternated so
    groups periodically converge (cheap cross-group offload) and diverge
    (links beyond ``max_range`` ⇒ ρ = 0).
    """

    def __init__(self, params: RPGParams, n_groups: int = 2, seed: int = 0):
        if params.n_uavs % n_groups:
            raise ValueError(f"{params.n_uavs} UAVs not divisible into "
                             f"{n_groups} groups")
        self.p = params
        self.n_groups = n_groups
        per = params.n_uavs // n_groups
        self.group_of = np.repeat(np.arange(n_groups), per)
        self._groups = []
        for g in range(n_groups):
            gp = dataclasses.replace(params, n_uavs=per)
            self._groups.append(RPGMobility(gp, seed=seed * 7919 + g))
        # Opposite-corner sweeps: even groups run SW→NE, odd groups NW→SE,
        # so group pairs meet mid-area and separate toward opposite corners.
        for g, mob in enumerate(self._groups):
            lo = params.member_radius_m
            hi = params.area_m - params.member_radius_m
            if g % 2 == 1:
                mob._start = np.array([lo, hi])
                mob._end = np.array([hi, lo])

    @property
    def n_uavs(self) -> int:
        return self.p.n_uavs

    def positions(self, num_steps: int, seed: int | None = None,
                  t0: int = 0) -> np.ndarray:
        """(T, N, 3) planned positions for t = t0..t0+T-1.  ``t0`` lets the
        simulator window the one planned trajectory instead of replaying from
        mission start each epoch."""
        out = np.zeros((num_steps, self.p.n_uavs, 3))
        per = self.p.n_uavs // self.n_groups
        for g, mob in enumerate(self._groups):
            gseed = (seed * 104729 + g) if seed is not None else None
            # Window the group's trajectory: generate t0+T steps then slice —
            # keeps the jittered member offsets deterministic in t0.
            pos = mob.positions(t0 + num_steps, seed=gseed)
            out[:, g * per:(g + 1) * per] = pos[t0:]
        return out

    def predicted_rates(self, num_steps: int, radio: RadioParams | None = None,
                        seed: int | None = None, t0: int = 0) -> np.ndarray:
        """(T, N, N) ρ_{i,k}(t) — inter-group pairs hit ρ = 0 when their
        groups separate beyond ``max_range`` (the OULD-MP scenario class)."""
        pos = self.positions(num_steps, seed=seed, t0=t0)
        return np.stack([rate_matrix(pos[t], radio) for t in range(pos.shape[0])])
