"""OULD-MP — one-shot placement over a predicted mobility horizon (§III-C).

Thin convenience layer: builds the (T, N, N) predicted rate tensor from the
RPG mobility model and hands it to :func:`solve_ould` (the time-expanded
objective of Eq. 14 lives in ``Problem.transfer_cost``, which sums seconds/
byte over the horizon; disconnections at any predicted step price the pair
out, so the chosen placement never relies on a link about to vanish).

Also provides the *static re-solve* baseline the paper compares against
(OULD executed at every time step, Fig. 13/14) and the offline-fixed
baseline of [32] (solve once at t=0 then hold the placement).

.. deprecated::
    These mobility-model convenience wrappers are legacy shims kept for one
    release.  New code should use the planner registry —
    ``get_planner("ould-mp").plan(problem, HorizonView(predicted_rates))``
    — which needs no bespoke ``rate_fn``/mobility signature (see
    :mod:`repro.core.planner` and DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .latency import Evaluation, evaluate
from .mobility import RPGMobility
from .ould import Problem, Solution, solve_ould
from .profiles import ModelProfile
from .radio import RadioParams


@dataclasses.dataclass
class MPResult:
    solution: Solution
    per_step: list[Evaluation]      # placement evaluated at each realized step
    runtime_s: float


def _step_problem(base: Problem, rates_t: np.ndarray) -> Problem:
    return Problem(base.profile, base.mem_cap, base.comp_cap, rates_t,
                   base.sources, base.compute_speed, base.rate_unit_bytes)


def solve_ould_mp(profile: ModelProfile, mem_cap: np.ndarray,
                  comp_cap: np.ndarray, sources: np.ndarray,
                  mobility: RPGMobility, horizon: int,
                  radio: RadioParams | None = None,
                  compute_speed: np.ndarray | None = None,
                  solver: str = "ilp", **kw) -> MPResult:
    """One-shot OULD-MP: a single placement optimal over t ∈ {1..T}."""
    t0 = time.perf_counter()
    rates = mobility.predicted_rates(horizon, radio)      # (T, N, N)
    prob = Problem(profile, mem_cap, comp_cap, rates, sources, compute_speed)
    sol = solve_ould(prob, solver=solver, **kw)  # type: ignore[arg-type]
    per_step = [evaluate(_step_problem(prob, rates[t]), sol)
                for t in range(horizon)]
    return MPResult(sol, per_step, time.perf_counter() - t0)


def solve_static_resolve(profile: ModelProfile, mem_cap: np.ndarray,
                         comp_cap: np.ndarray, sources: np.ndarray,
                         mobility: RPGMobility, horizon: int,
                         radio: RadioParams | None = None,
                         compute_speed: np.ndarray | None = None,
                         solver: str = "ilp", **kw) -> MPResult:
    """Baseline: re-run OULD at every time step (§III-C complexity argument —
    runtime ≈ T × single solve; Fig. 14)."""
    t0 = time.perf_counter()
    rates = mobility.predicted_rates(horizon, radio)
    per_step: list[Evaluation] = []
    last: Solution | None = None
    for t in range(horizon):
        prob_t = Problem(profile, mem_cap, comp_cap, rates[t], sources,
                         compute_speed)
        last = solve_ould(prob_t, solver=solver, **kw)  # type: ignore[arg-type]
        per_step.append(evaluate(prob_t, last))
    assert last is not None
    return MPResult(last, per_step, time.perf_counter() - t0)


def solve_offline_fixed(profile: ModelProfile, mem_cap: np.ndarray,
                        comp_cap: np.ndarray, sources: np.ndarray,
                        mobility: RPGMobility, horizon: int,
                        radio: RadioParams | None = None,
                        compute_speed: np.ndarray | None = None,
                        solver: str = "ilp", **kw) -> MPResult:
    """Baseline of [32] (Fig. 13): optimize once on the t=0 snapshot, then
    hold that placement while the swarm moves — requests served over links
    that may degrade to disconnection (evaluation returns inf latency then)."""
    t0 = time.perf_counter()
    rates = mobility.predicted_rates(horizon, radio)
    prob0 = Problem(profile, mem_cap, comp_cap, rates[0], sources,
                    compute_speed)
    sol = solve_ould(prob0, solver=solver, **kw)  # type: ignore[arg-type]
    per_step = [evaluate(_step_problem(prob0, rates[t]), sol)
                for t in range(horizon)]
    return MPResult(sol, per_step, time.perf_counter() - t0)
