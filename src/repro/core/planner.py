"""Unified placement-strategy API: ``Planner`` / ``TopologyView`` / ``Plan``.

The paper's evaluation is a bake-off between placement strategies (OULD ILP,
OULD DP, OULD-MP, three heuristics, and the warm-started incremental solver),
but each grew its own call signature.  This module is the single seam every
consumer goes through instead:

* :class:`TopologyView` — what the strategy is allowed to know about the
  network.  A :class:`SnapshotView` carries one ``(N, N)`` rate matrix (the
  information a real swarm estimates from its current links); a
  :class:`HorizonView` carries the predicted ``(T, N, N)`` sequence the
  OULD-MP objective (Eq. 14) sums over.  Both carry the optional ``alive``
  mask — a dead node's capacity and links are zeroed uniformly here instead
  of ad hoc at every call site.
* :class:`Planner` — the protocol: ``plan(problem, view) -> Plan``.  A
  planner declares the view kinds it supports (single-snapshot heuristics
  reject horizon views instead of silently using ``rates[0]``).  Planners may
  be stateful: the ``incremental`` planner caches placements and constraint
  structure across successive ``plan()`` calls.
* :class:`Plan` — a :class:`~repro.core.ould.Solution` plus provenance
  (``planner_name``, ``solve_stats``, ``warm``) and the bound problem it was
  solved against, bridging directly into :func:`~repro.core.placement.
  to_stages` and :func:`~repro.core.latency.evaluate`.
* A string-keyed registry — ``get_planner("ould-ilp" | "ould-dp" |
  "ould-dp-sparse" | "ould-mp" | "nearest" | "hrm" | "nearest-hrm" |
  "incremental" | "incremental-sparse")`` — so runtimes and benchmarks
  iterate strategies by name and a new strategy (reliability-aware LLHR, a
  DRL policy) is a one-file plug-in: ``@register_planner("my-strategy")``
  and every consumer can run it.

``ould-dp-sparse`` / ``incremental-sparse`` pin the k-candidate pruned DP
engine (sub-quadratic in swarm size; admission-identical to the dense DP
via its fallback ladder) — the N ≥ 50 serving regime; ``sparse_k``
overrides the √N default candidate budget.

Planner constructors accept a *uniform* option set and ignore options they
do not consume (``HeuristicPlanner`` ignores ``solver=``), so registry-driven
callers can build every strategy from one option dict.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol, runtime_checkable

import numpy as np

from .heuristics import solve_heuristic
from .latency import Evaluation, evaluate
from .ould import (IncrementalSolver, Problem, ResolveStats, Solution,
                   solve_ould)
from .placement import Stage, to_stages

SNAPSHOT = "snapshot"
HORIZON = "horizon"


# ---------------------------------------------------------------------------
# TopologyView — what a strategy may know about the network
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologyView:
    """A view of the link topology handed to a planner.

    ``rates`` is in bits/s (the :class:`Problem` convention).  ``alive`` marks
    per-node liveness: ``bind`` zeroes a dead node's capacities *and* every
    incident link (ρ = 0 ⇔ disconnected), which is the single place that
    masking rule lives now.
    """

    rates: np.ndarray                  # (N, N) or (T, N, N)
    alive: np.ndarray | None = None    # (N,) bool, None ⇒ all alive

    kind = "abstract"

    @property
    def n_nodes(self) -> int:
        return int(self.rates.shape[-1])

    @property
    def horizon(self) -> int:
        return 1 if self.rates.ndim == 2 else int(self.rates.shape[0])

    def effective_rates(self) -> np.ndarray:
        """Rates with dead nodes' links zeroed (a copy iff masking applies)."""
        alive = self.alive
        if alive is None or bool(np.all(alive)):
            return self.rates
        out = self.rates.copy()
        if out.ndim == 3:
            out[:, ~alive, :] = 0.0
            out[:, :, ~alive] = 0.0
        else:
            out[~alive, :] = 0.0
            out[:, ~alive] = 0.0
        return out

    def bind(self, problem: Problem) -> Problem:
        """The problem actually solved: this view's rates substituted in and
        dead nodes' capacities zeroed."""
        mem, comp = problem.mem_cap, problem.comp_cap
        if self.alive is not None and not bool(np.all(self.alive)):
            mem = np.where(self.alive, mem, 0.0)
            comp = np.where(self.alive, comp, 0.0)
        # replace(), not a positional rebuild: provenance fields
        # (comm_source) must survive the bind into Plan.problem.
        return dataclasses.replace(problem, mem_cap=mem, comp_cap=comp,
                                   rates=self.effective_rates())


@dataclasses.dataclass(frozen=True)
class SnapshotView(TopologyView):
    """One ``(N, N)`` rate matrix — a fixed-time-step network configuration
    (the only information the paper's heuristics are designed for)."""

    kind = SNAPSHOT

    def __post_init__(self):
        if self.rates.ndim != 2:
            raise ValueError(
                f"SnapshotView needs (N, N) rates, got {self.rates.shape}")


@dataclasses.dataclass(frozen=True)
class HorizonView(TopologyView):
    """A predicted ``(T, N, N)`` rate sequence — the OULD-MP horizon whose
    per-step seconds/byte the Eq. 14 objective sums."""

    kind = HORIZON

    def __post_init__(self):
        if self.rates.ndim != 3:
            raise ValueError(
                f"HorizonView needs (T, N, N) rates, got {self.rates.shape}")

    def snapshot(self, t: int = 0) -> SnapshotView:
        """The single-step view at predicted step ``t``."""
        return SnapshotView(self.rates[t], self.alive)


def make_view(rates: np.ndarray,
              alive: np.ndarray | None = None) -> TopologyView:
    """Snapshot or horizon view inferred from the rate array's rank."""
    cls = SnapshotView if rates.ndim == 2 else HorizonView
    return cls(rates, alive)


# ---------------------------------------------------------------------------
# Degraded views — the prediction-quality axis (ROADMAP)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StaleView(SnapshotView):
    """A snapshot the planner *believes* is current but was captured
    ``age_ticks`` earlier.  ``kind`` stays ``"snapshot"`` — staleness is
    invisible to the strategy, which is the point: serving happens on the
    realized topology, so the gap between the two prices the value of fresh
    link estimates.  The caller supplies the old rate matrix (it owns the
    history); ``age_ticks`` is provenance."""

    age_ticks: int = 0


@dataclasses.dataclass(frozen=True)
class NoisyHorizonView(HorizonView):
    """A predicted horizon whose rates carry multiplicative lognormal error
    — imperfect mobility prediction.  ``noise_std`` is the σ of the
    mean-preserving perturbation ``exp(N(−σ²/2, σ))`` applied per entry;
    the planner sees only the corrupted rates (``kind`` stays
    ``"horizon"``)."""

    noise_std: float = 0.0

    @classmethod
    def corrupt(cls, view: HorizonView, noise_std: float,
                seed: int = 0) -> "NoisyHorizonView":
        """Corrupt ``view``'s predicted rates (deterministic per seed).
        Disconnected pairs (ρ = 0) stay disconnected — noise degrades rate
        estimates, it does not invent links."""
        if noise_std <= 0.0:
            return cls(view.rates, view.alive, noise_std=0.0)
        rng = np.random.default_rng(seed)
        noise = np.exp(rng.normal(-0.5 * noise_std ** 2, noise_std,
                                  view.rates.shape))
        return cls(view.rates * noise, view.alive, noise_std=noise_std)


# ---------------------------------------------------------------------------
# Plan — a Solution with provenance
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Plan:
    """What a planner returns: the solution, who produced it, and against
    what.  ``problem`` is the *bound* problem (view applied) the numbers are
    valid for — :meth:`evaluate` and :meth:`stages` need no other context."""

    solution: Solution
    planner_name: str
    view_kind: str
    problem: Problem
    solve_stats: ResolveStats | None = None
    warm: bool = False

    # -- Solution contract pass-throughs -----------------------------------
    @property
    def assign(self) -> np.ndarray:
        return self.solution.assign

    @property
    def admitted(self) -> np.ndarray:
        return self.solution.admitted

    @property
    def objective(self) -> float:
        return self.solution.objective

    @property
    def status(self) -> str:
        return self.solution.status

    @property
    def n_admitted(self) -> int:
        return self.solution.n_admitted

    @property
    def solve_time_s(self) -> float:
        return self.solution.solve_time_s

    # -- bridges ------------------------------------------------------------
    def stages(self, r: int = 0) -> list[Stage]:
        """Pipeline stages of request ``r`` (rejects the ``-1`` sentinel)."""
        if not self.solution.admitted[r]:
            raise ValueError(f"request {r} was rejected; it has no stages")
        return to_stages(self.solution.assign[r])

    def evaluate(self) -> Evaluation:
        """Paper metrics of this plan on the problem it was solved against."""
        return evaluate(self.problem, self.solution)

    def evaluate_per_step(self,
                          rates: np.ndarray | None = None) -> list[Evaluation]:
        """The held placement judged against each step's realized snapshot
        (paper Fig. 9–13): by default the bound problem's own horizon, or an
        explicit ``(T, N, N)`` sequence (e.g. to play an offline-fixed
        snapshot plan forward while the swarm moves)."""
        r = self.problem.rates if rates is None else rates
        r3 = r[None] if r.ndim == 2 else r
        return [evaluate(dataclasses.replace(self.problem, rates=r3[t]),
                         self.solution) for t in range(r3.shape[0])]


# ---------------------------------------------------------------------------
# Planner protocol + implementations
# ---------------------------------------------------------------------------

@runtime_checkable
class Planner(Protocol):
    """A placement strategy.  ``view_kinds`` lists the view kinds accepted,
    most-preferred first (``preferred_view`` is what capability-driven
    callers build when they could offer either)."""

    name: str
    view_kinds: tuple[str, ...]

    def plan(self, problem: Problem, view: TopologyView, *,
             request_ids=None) -> Plan:
        """Place ``problem``'s requests using what ``view`` reveals.

        ``request_ids`` carries stable stream identity for stateful planners
        (placement inheritance across calls); stateless planners ignore it.
        """
        ...


class _PlannerBase:
    name: str = "?"
    view_kinds: tuple[str, ...] = (SNAPSHOT,)

    @property
    def preferred_view(self) -> str:
        return self.view_kinds[0]

    def _require_view(self, view: TopologyView) -> None:
        if view.kind not in self.view_kinds:
            raise ValueError(
                f"planner {self.name!r} supports {self.view_kinds} views, "
                f"got {view.kind!r}")


class OuldPlanner(_PlannerBase):
    """Cold OULD solve per call (paper §III-B): the exact ILP or the greedy
    sequential DP.  Registered as ``ould-ilp`` / ``ould-dp`` (snapshot) and,
    over a predicted horizon, as ``ould-mp`` (Eq. 14: one placement optimal
    over t ∈ {1..T}).  The ILP constraint structure is cached across calls on
    same-shaped instances."""

    def __init__(self, solver: str = "ilp", *, name: str | None = None,
                 view_kinds: tuple[str, ...] = (SNAPSHOT,),
                 include_compute: bool = False, tight: bool = True,
                 gamma_relaxed: bool = True, time_limit: float | None = None,
                 mip_rel_gap: float = 1e-6,
                 max_path_cost: float | None = None,
                 sparse_k: int | None = None, batch_solve: bool = False,
                 capacity_repair: str = "halve",
                 **_ignored: Any):
        self.name = name or f"ould-{solver}"
        self.view_kinds = view_kinds
        self.solver = solver
        self._kw = dict(include_compute=include_compute, tight=tight,
                        gamma_relaxed=gamma_relaxed, time_limit=time_limit,
                        mip_rel_gap=mip_rel_gap, max_path_cost=max_path_cost,
                        sparse_k=sparse_k, batch_solve=batch_solve,
                        capacity_repair=capacity_repair)
        self._constraint_cache: dict = {}

    def plan(self, problem: Problem, view: TopologyView, *,
             request_ids=None) -> Plan:
        self._require_view(view)
        bound = view.bind(problem)
        sol = solve_ould(bound, solver=self.solver,  # type: ignore[arg-type]
                         constraint_cache=self._constraint_cache, **self._kw)
        return Plan(sol, self.name, view.kind, bound,
                    solve_stats=sol.dp_stats)


class HeuristicPlanner(_PlannerBase):
    """The paper's greedy hand-off baselines (§IV-A).  Snapshot-only by
    construction — 'designed for a single network configuration obtained from
    a fixed time step' — so a horizon view is an error, not a truncation."""

    view_kinds = (SNAPSHOT,)

    def __init__(self, kind: str, *, name: str | None = None,
                 **_ignored: Any):
        self.kind = kind
        self.name = name or kind.replace("_", "-")

    def plan(self, problem: Problem, view: TopologyView, *,
             request_ids=None) -> Plan:
        self._require_view(view)
        bound = view.bind(problem)
        sol = solve_heuristic(bound, self.kind)  # type: ignore[arg-type]
        return Plan(sol, self.name, view.kind, bound)


class IncrementalPlanner(_PlannerBase):
    """Stateful warm-started planner wrapping :class:`IncrementalSolver`.

    Successive ``plan()`` calls on the same instance keep placements of
    requests untouched by topology drift, reuse the cached ILP constraint
    structure, and re-price only changed rows of the transfer-cost matrix.
    Request identity across calls comes from ``problem.sources`` row order by
    default; callers tracking stable stream ids pass ``request_ids``.

    The underlying solver is built lazily from the first problem's profile
    and capacities; a later problem with different capacities or profile
    resets the warm state (a new pool is a new planner, effectively).
    """

    view_kinds = (SNAPSHOT, HORIZON)

    def __init__(self, solver: str = "dp", *, name: str = "incremental",
                 view_kinds: tuple[str, ...] | None = None, warm: bool = True,
                 rel_change: float = 0.05, price_rel_change: float = 0.0,
                 max_path_cost: float | None = None,
                 include_compute: bool = False,
                 sparse_k: int | None = None, batch_solve: bool = False,
                 capacity_repair: str = "halve",
                 **_ignored: Any):
        self.name = name
        if view_kinds is not None:
            self.view_kinds = view_kinds
        self.solver = solver
        self.warm = warm
        self.rel_change = rel_change
        self.price_rel_change = price_rel_change
        self.max_path_cost = max_path_cost
        self.include_compute = include_compute
        self.sparse_k = sparse_k
        self.batch_solve = batch_solve
        self.capacity_repair = capacity_repair
        self._inc: IncrementalSolver | None = None
        self._pool_key: tuple | None = None

    def _solver_for(self, problem: Problem) -> IncrementalSolver:
        key = (problem.profile, problem.mem_cap.tobytes(),
               problem.comp_cap.tobytes(),
               None if problem.compute_speed is None
               else problem.compute_speed.tobytes())
        if self._inc is None or key != self._pool_key:
            self._inc = IncrementalSolver(
                problem.profile, problem.mem_cap, problem.comp_cap,
                problem.compute_speed, solver=self.solver,  # type: ignore[arg-type]
                include_compute=self.include_compute,
                rel_change=self.rel_change,
                price_rel_change=self.price_rel_change,
                max_path_cost=self.max_path_cost,
                rate_unit_bytes=problem.rate_unit_bytes,
                sparse_k=self.sparse_k, batch_solve=self.batch_solve,
                capacity_repair=self.capacity_repair)
            self._pool_key = key
        return self._inc

    def plan(self, problem: Problem, view: TopologyView, *,
             request_ids=None, cold: bool = False) -> Plan:
        self._require_view(view)
        inc = self._solver_for(problem)
        # IncrementalSolver applies the alive mask itself (capacities AND
        # links) — hand it the raw view so its drift detection sees flips.
        step = inc.resolve if (self.warm and not cold) else inc.solve
        sol, stats = step(view.rates, problem.sources, request_ids,
                          view.alive)
        return Plan(sol, self.name, view.kind, view.bind(problem),
                    solve_stats=stats, warm=not stats.cold)

    def reset(self) -> None:
        """Drop all warm state (placements, caches, references)."""
        self._inc = None
        self._pool_key = None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Planner]] = {}


def register_planner(name: str,
                     factory: Callable[..., Planner] | None = None):
    """Register a planner factory under ``name``; usable as a decorator:

        @register_planner("my-strategy")
        class MyPlanner: ...
    """
    def _register(f: Callable[..., Planner]) -> Callable[..., Planner]:
        _REGISTRY[name] = f
        return f
    return _register(factory) if factory is not None else _register


def available_planners() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_planner(name: str, **options: Any) -> Planner:
    """Instantiate the registered strategy ``name``.

    Every call returns a *fresh* instance (stateful planners keep their warm
    caches per instance, not globally).  Unknown option keys are ignored by
    the planner that does not consume them, so one option dict can configure
    a whole registry sweep.
    """
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown planner {name!r}; "
                       f"available: {available_planners()}") from None
    return factory(**options)


def _fixed_solver(solver: str, name: str):
    """Factory for a fixed-engine OULD planner: a caller-supplied ``solver``
    option (from a uniform registry-sweep option dict) is ignored — the
    registry name pins the engine."""
    def factory(**o: Any) -> Planner:
        o.pop("solver", None)
        return OuldPlanner(solver, name=name, **o)
    return factory


register_planner("ould-ilp", _fixed_solver("ilp", "ould-ilp"))
register_planner("ould-dp", _fixed_solver("dp", "ould-dp"))
register_planner("ould-dp-sparse", _fixed_solver("dp-sparse", "ould-dp-sparse"))
register_planner("nearest", lambda **o: HeuristicPlanner("nearest", **o))
register_planner("hrm", lambda **o: HeuristicPlanner("hrm", **o))
register_planner(
    "nearest-hrm",
    lambda **o: HeuristicPlanner("nearest_hrm", name="nearest-hrm", **o))
register_planner(
    "incremental",
    lambda **o: IncrementalPlanner(**{"solver": "dp", **o}))


@register_planner("incremental-sparse")
def _incremental_sparse_factory(**o: Any) -> Planner:
    """Warm-started planner over the pruned k-candidate DP: the registry
    name pins the engine (a caller-supplied ``solver`` option from a uniform
    registry-sweep dict is ignored)."""
    o.pop("solver", None)
    return IncrementalPlanner("dp-sparse", name="incremental-sparse", **o)


@register_planner("ould-mp")
def _ould_mp_factory(*, warm: bool = False, solver: str | None = None,
                     **o: Any) -> Planner:
    """OULD-MP: the horizon-objective strategy (Eq. 14).  Cold by default —
    the paper's one-shot placement; ``warm=True`` yields the serving-loop
    variant that warm-starts successive horizon re-solves."""
    if warm:
        return IncrementalPlanner(solver or "dp", name="ould-mp",
                                  view_kinds=(HORIZON,), **o)
    return OuldPlanner(solver or "ilp", name="ould-mp",
                       view_kinds=(HORIZON,), **o)
