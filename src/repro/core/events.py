"""Discrete-event primitives for the swarm serving simulator.

The paper evaluates placement policies on a *moving* swarm serving a
*stream* of inference requests (§III-C mobility, §IV scenarios).  This
module provides the event substrate the simulator in
``repro.runtime.swarm`` schedules on:

* :class:`EventQueue` — a stable min-heap keyed on (time, seq) so ties
  resolve in insertion order, which keeps runs bit-reproducible.
* :func:`poisson_process` — request arrival times (the streaming-request
  workload of LLHR/DRL follow-ups; exponential inter-arrivals).
* :func:`churn_events` — node failure/rejoin pairs with exponential
  time-between-failure and repair times (the "UAV drops out of the swarm"
  disturbance OULD-MP cannot predict, unlike mobility).

Everything is driven by an externally supplied ``numpy.random.Generator``
so a fixed seed reproduces the exact event tape.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq

import numpy as np


class EventKind(enum.IntEnum):
    ARRIVAL = 0        # a new inference stream starts (payload: request id)
    DEPARTURE = 1      # a stream ends and releases its reservation
    NODE_FAIL = 2      # payload: node id — capacity and links go to zero
    NODE_REJOIN = 3    # payload: node id — node restored
    MOBILITY_TICK = 4  # advance positions one step, re-sample rate matrix
    EPOCH = 5          # re-placement boundary (re-solve OULD/OULD-MP)
    QUEUE_ADVANCE = 6  # drain the tick's emitted frames through node queues


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    time: float
    seq: int                     # tie-breaker: insertion order
    kind: EventKind = dataclasses.field(compare=False)
    payload: int = dataclasses.field(compare=False, default=-1)


class EventQueue:
    """Stable priority queue of :class:`Event`."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = 0

    def push(self, time: float, kind: EventKind, payload: int = -1) -> Event:
        ev = Event(float(time), self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Event | None:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


def poisson_process(rng: np.random.Generator, rate_hz: float,
                    horizon_s: float) -> np.ndarray:
    """Arrival times of a homogeneous Poisson process on [0, horizon_s)."""
    if rate_hz <= 0.0:
        return np.zeros(0)
    # Draw in blocks of the expected count + safety margin until past horizon.
    times: list[float] = []
    t = 0.0
    block = max(8, int(rate_hz * horizon_s * 1.5) + 8)
    while t < horizon_s:
        gaps = rng.exponential(1.0 / rate_hz, block)
        for g in gaps:
            t += g
            if t >= horizon_s:
                break
            times.append(t)
    return np.asarray(times)


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    time: float
    node: int
    kind: EventKind  # NODE_FAIL or NODE_REJOIN


def churn_events(rng: np.random.Generator, n_nodes: int, horizon_s: float,
                 mtbf_s: float, mttr_s: float,
                 protected: frozenset[int] = frozenset()) -> list[ChurnEvent]:
    """Exponential fail/rejoin tape per node.

    ``mtbf_s`` — mean time between failures (∞ or <=0 disables churn);
    ``mttr_s`` — mean time to repair.  ``protected`` nodes never fail
    (e.g. hotspot/source UAVs, whose loss would make every policy reject).
    """
    out: list[ChurnEvent] = []
    if mtbf_s <= 0 or not np.isfinite(mtbf_s):
        return out
    for node in range(n_nodes):
        if node in protected:
            continue
        t = float(rng.exponential(mtbf_s))
        while t < horizon_s:
            out.append(ChurnEvent(t, node, EventKind.NODE_FAIL))
            t += float(rng.exponential(mttr_s))
            if t >= horizon_s:
                break
            out.append(ChurnEvent(t, node, EventKind.NODE_REJOIN))
            t += float(rng.exponential(mtbf_s))
    out.sort(key=lambda e: (e.time, e.node))
    return out
