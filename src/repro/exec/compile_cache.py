"""Persistent XLA compilation cache for the jitted ``apply_layers`` closures.

Engine warmup used to be per-process and thrown away: every simulation,
benchmark, and churn-rejoined node paid a fresh XLA compile per layer range.
This module points JAX's compilation cache at a durable directory so a
recompile of an already-seen closure is a disk hit — measured ~10× faster
on the CPU backend, which is what lets a node that joins mid-scenario warm
in milliseconds (``ExecutionEngine.warm_start``).

Lifecycle:

* :func:`enable` — set the cache directory (argument > the standard
  ``JAX_COMPILATION_CACHE_DIR`` env var > a per-user default) and drop the
  min-compile-time / min-entry-size thresholds so CPU kernels are cached at
  all (the defaults assume multi-second accelerator compiles);
* :func:`disable` — detach the directory (in-memory jit cache untouched);
* :func:`clear_in_memory` — drop the in-memory executable cache, which is
  exactly what a process restart does: the next compile of the same HLO
  must go through the persistent layer, making warm-vs-cold measurable
  in-process (:func:`measure_warm_start`, bench E6's strict lock).

CI keeps the directory across runs with ``actions/cache`` keyed on the JAX
version, so the suite's compiles warm across workflow runs too.
"""

from __future__ import annotations

import dataclasses
import os
import time
from pathlib import Path
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..models import cnn

DEFAULT_DIR = Path.home() / ".cache" / "repro-jax-cache"


def _reset_backend_cache() -> None:
    """JAX initializes its persistent-cache singleton on first compile and
    never re-reads the config afterwards; without this reset, enabling (or
    re-pointing) the cache in a process that already compiled something is
    a silent no-op."""
    try:
        from jax._src import compilation_cache
        compilation_cache.reset_cache()
    except (ImportError, AttributeError):   # private API drifted: config
        pass                                # update alone still covers the
                                            # enable-before-first-compile path


def enable(cache_dir: str | os.PathLike | None = None) -> Path:
    """Attach the persistent compilation cache; returns the directory."""
    path = Path(cache_dir if cache_dir is not None
                else os.environ.get("JAX_COMPILATION_CACHE_DIR", DEFAULT_DIR))
    path.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    # CPU closures compile in ~0.1–1 s and produce small executables; the
    # default thresholds would silently cache nothing.
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    _reset_backend_cache()
    return path


def disable() -> None:
    jax.config.update("jax_compilation_cache_dir", None)
    _reset_backend_cache()


def is_enabled() -> bool:
    return jax.config.jax_compilation_cache_dir is not None


def cache_dir() -> Path | None:
    d = jax.config.jax_compilation_cache_dir
    return Path(d) if d else None


def clear_in_memory() -> None:
    """Drop compiled executables from process memory (what a restart does);
    the persistent directory is untouched, so the next compile is a disk
    hit when the cache is enabled."""
    jax.clear_caches()


@dataclasses.dataclass(frozen=True)
class WarmStartReport:
    """Cold-vs-warm compile walls over one set of layer ranges."""

    ranges: tuple[tuple[int, int], ...]
    cold_s: tuple[float, ...]     # fresh compile, empty persistent cache
    warm_s: tuple[float, ...]     # recompile after clear_in_memory(): disk hit

    @property
    def cold_total_s(self) -> float:
        return float(sum(self.cold_s))

    @property
    def warm_total_s(self) -> float:
        return float(sum(self.warm_s))

    @property
    def speedup(self) -> float:
        return (self.cold_total_s / self.warm_total_s
                if self.warm_total_s > 0 else float("inf"))

    def summary(self) -> str:
        return (f"warm start: {len(self.ranges)} ranges, "
                f"cold {self.cold_total_s:.3f}s -> warm "
                f"{self.warm_total_s * 1e3:.1f}ms "
                f"({self.speedup:.1f}x)")


def measure_warm_start(layer_fns: Sequence[Callable],
                       ranges: Sequence[tuple[int, int]],
                       frame, *, cache_dir: str | os.PathLike
                       ) -> WarmStartReport:
    """Measure the persistent cache's churn-rejoin benefit on ``ranges``.

    Pass one: compile each range's closure against ``cache_dir`` (cold —
    the caller hands a fresh directory for a deterministic baseline, which
    is why benches do NOT reuse the CI-level cache here).  Then
    :func:`clear_in_memory` simulates the process restart of a rejoining
    node and pass two recompiles the same ranges — every compile now lands
    on the disk cache.  ``ranges`` must chain from layer 0 (each start
    produced by an earlier range) so boundary activations can propagate.

    The previously configured cache directory is restored on exit.
    """
    ranges = tuple((int(s), int(e)) for s, e in ranges)
    if not ranges or ranges[0][0] != 0:
        raise ValueError(f"ranges must chain from layer 0, got {ranges}")
    prev = jax.config.jax_compilation_cache_dir
    enable(cache_dir)
    fns = list(layer_fns)

    def build(s: int, e: int) -> Callable:
        @jax.jit
        def _run(x, _s=s, _e=e):
            return cnn.apply_layers(fns, x, _s, _e)
        return _run

    def timed_pass() -> tuple[list[float], dict]:
        acts = {0: jnp.asarray(frame)[None]}
        walls = []
        for s, e in ranges:
            if s not in acts:
                raise ValueError(f"range ({s}, {e}) has no produced start")
            fn = build(s, e)
            t0 = time.perf_counter()
            y = jax.block_until_ready(fn(acts[s]))
            walls.append(time.perf_counter() - t0)
            acts[e] = y
        return walls, acts

    try:
        cold, _ = timed_pass()
        clear_in_memory()                  # the "process restart"
        warm, _ = timed_pass()
    finally:
        if prev:
            jax.config.update("jax_compilation_cache_dir", prev)
            _reset_backend_cache()
        else:
            disable()
    return WarmStartReport(ranges, tuple(cold), tuple(warm))
