"""Plan-faithful distributed execution (`repro.exec`) — DESIGN.md §5.

The optimizer stack (``core/``) *prices* a placement analytically; this
package *runs* it.  Any :class:`~repro.core.planner.Plan` compiles into a
:class:`StageGraph` (contiguous layer ranges per node, shared stages deduped
across requests for batching), the :class:`ExecutionEngine` executes each
stage as a jitted ``apply_layers`` closure and records wall-clock per stage
and per transfer, and :mod:`repro.exec.calibrate` closes the loop: measured
stage timings update :class:`~repro.core.profiles.LayerProfile` compute
vectors so every registered planner re-solves against realized numbers.
"""

from .calibrate import (CalibrationReport, calibrate_profile,
                        calibrated_problem, measured_layer_seconds,
                        reconcile)
from .engine import ExecutionEngine, ExecutionReport, StageTiming, layer_fns_for
from .stage_graph import (StageGraph, StageTask, Transfer, coalesce_graphs,
                          compile_plan)

__all__ = [
    "CalibrationReport", "ExecutionEngine", "ExecutionReport", "StageGraph",
    "StageTask", "StageTiming", "Transfer", "calibrate_profile",
    "calibrated_problem", "coalesce_graphs", "compile_plan", "layer_fns_for",
    "measured_layer_seconds", "reconcile",
]
