"""Plan-faithful distributed execution (`repro.exec`) — DESIGN.md §5/§7.

The optimizer stack (``core/``) *prices* a placement analytically; this
package *runs* it.  Any :class:`~repro.core.planner.Plan` compiles into a
:class:`StageGraph` (contiguous layer ranges per node, shared stages deduped
across requests for batching), the :class:`ExecutionEngine` executes each
stage as a jitted ``apply_layers`` closure — routing every boundary transfer
through a :mod:`repro.transport` backend — and records wall-clock per stage
and per transfer.  :mod:`repro.exec.calibrate` closes both loops: measured
stage timings update :class:`~repro.core.profiles.LayerProfile` compute
vectors, and a byte-moving transport's realized per-link bandwidth updates
the rates (``calibrate_rates``), so every registered planner re-solves
against realized numbers on both axes.  :mod:`repro.exec.compile_cache`
makes the jit warmup persistent across processes (churn-rejoin warm start).
"""

from . import compile_cache
from .calibrate import (CalibrationReport, calibrate_profile, calibrate_rates,
                        calibrated_problem, measured_layer_seconds,
                        reconcile)
from .compile_cache import WarmStartReport, measure_warm_start
from .engine import ExecutionEngine, ExecutionReport, StageTiming, layer_fns_for
from .stage_graph import (StageGraph, StageTask, Transfer, coalesce_graphs,
                          compile_plan, link_payload_bytes, stage_signature)

__all__ = [
    "CalibrationReport", "ExecutionEngine", "ExecutionReport", "StageGraph",
    "StageTask", "StageTiming", "Transfer", "WarmStartReport",
    "calibrate_profile", "calibrate_rates", "calibrated_problem",
    "coalesce_graphs", "compile_cache", "compile_plan", "layer_fns_for",
    "link_payload_bytes", "measure_warm_start", "measured_layer_seconds",
    "reconcile", "stage_signature",
]
