"""Plan → StageGraph compiler.

OULD emits ``assign[r, j] = node``; :func:`~repro.core.placement.to_stages`
groups each admitted request's path into contiguous layer ranges.  The graph
compiled here is the *executable* form of a whole plan:

* one :class:`StageTask` per unique ``(node, layer_start, layer_end)`` —
  requests whose paths share a stage are batched into one kernel launch
  (the dedup that makes hotspot request streams cheap to execute);
* one :class:`Transfer` per request per cut point, priced from
  ``Problem.transfer_cost()`` — the same seconds/byte matrix the OULD
  objective minimized, so predicted and executed latency decompose over
  identical terms.

Tasks are topologically ordered by ``layer_start`` (ties by node id): every
transfer's producer task precedes its consumer, which is all the engine's
tick loop needs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.ould import Problem
from ..core.placement import to_stages
from ..core.planner import Plan


@dataclasses.dataclass(frozen=True)
class StageTask:
    """One batched kernel launch: layers [layer_start, layer_end) on ``node``
    for every request in ``requests`` (ascending request rows)."""

    node: int
    layer_start: int   # inclusive
    layer_end: int     # exclusive
    requests: tuple[int, ...]

    @property
    def key(self) -> tuple[int, int, int]:
        return (self.node, self.layer_start, self.layer_end)

    @property
    def n_layers(self) -> int:
        return self.layer_end - self.layer_start


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One boundary activation shipment for one request.

    ``layer`` is the consuming layer index: the output of ``layer - 1``
    (or the source frame when ``layer == 0``) crosses the ``src_node →
    dst_node`` link.  ``delay_s`` is the analytic link delay —
    ``nbytes × spb[src, dst]`` with ``spb = Problem.transfer_cost()``.
    """

    request: int
    src_node: int
    dst_node: int
    layer: int
    nbytes: float
    delay_s: float


@dataclasses.dataclass(frozen=True)
class StageGraph:
    """The executable form of a plan: batched stage tasks in topological
    order plus every request's boundary transfers."""

    tasks: tuple[StageTask, ...]
    transfers: tuple[Transfer, ...]
    n_layers: int
    n_requests: int              # plan rows, including rejected ones
    requests: tuple[int, ...]    # admitted rows actually compiled

    @property
    def n_shared(self) -> int:
        """Stage launches saved by dedup (per-request stages − tasks)."""
        return sum(len(t.requests) for t in self.tasks) - len(self.tasks)

    def request_tasks(self, r: int) -> list[StageTask]:
        return [t for t in self.tasks if r in t.requests]

    def request_transfers(self, r: int) -> list[Transfer]:
        return [tr for tr in self.transfers if tr.request == r]

    def transfer_delay_s(self, r: int) -> float:
        return float(sum(tr.delay_s for tr in self.transfers
                         if tr.request == r))


def compile_plan(plan: Plan, *, problem: Problem | None = None,
                 requests: list[int] | None = None) -> StageGraph:
    """Compile a plan into its stage graph.

    ``problem`` defaults to the plan's bound problem (the instance its
    numbers are valid for); pass an override to re-price transfers against a
    different realized topology (the swarm simulator's per-tick snapshots).
    ``requests`` restricts compilation to a subset of admitted rows.
    """
    prob = problem if problem is not None else plan.problem
    spb = prob.transfer_cost()
    K = prob.profile.output_vector()
    Ks = prob.profile.input_bytes

    rows = [r for r in range(prob.n_requests) if plan.admitted[r]]
    if requests is not None:
        wanted = set(requests)
        rows = [r for r in rows if r in wanted]

    by_key: dict[tuple[int, int, int], list[int]] = {}
    transfers: list[Transfer] = []
    for r in rows:
        src = int(prob.sources[r])
        prev = src
        for st in to_stages(plan.assign[r]):
            by_key.setdefault((st.node, st.layer_start, st.layer_end),
                              []).append(r)
            if st.node != prev:
                nbytes = Ks if st.layer_start == 0 else K[st.layer_start - 1]
                transfers.append(Transfer(
                    r, prev, st.node, st.layer_start, float(nbytes),
                    float(nbytes * spb[prev, st.node])))
            prev = st.node

    tasks = tuple(StageTask(n, s, e, tuple(rs))
                  for (n, s, e), rs in sorted(by_key.items(),
                                              key=lambda kv: (kv[0][1],
                                                              kv[0][0])))
    return StageGraph(tasks, tuple(transfers), prob.n_layers,
                      prob.n_requests, tuple(rows))


def coalesce_graphs(graphs: list[StageGraph] | tuple[StageGraph, ...], *,
                    offsets: list[int] | None = None) -> StageGraph:
    """Batch stage launches *across arrival time*.

    ``compile_plan`` dedups shared stages within ONE plan; a serving runtime
    compiles one plan per admission round, so requests that arrive in
    different rounds but run the same ``(node, layer_start, layer_end)``
    stage still launch separately.  This merges several compiled graphs into
    one: request rows are re-identified by per-graph ``offsets`` (default:
    cumulative ``n_requests``, i.e. the graphs' plan rows stacked in order),
    tasks with equal keys coalesce into one batched launch, and transfers
    carry over with shifted request ids.  Executing the merged graph on the
    stacked frame array is exactly equivalent per request — same layer
    ranges, same link delays — but with fewer kernel launches (pinned by the
    E5 bench and the equivalence test).

    All graphs must share ``n_layers`` (one model).
    """
    if not graphs:
        raise ValueError("coalesce_graphs needs at least one graph")
    n_layers = graphs[0].n_layers
    if any(g.n_layers != n_layers for g in graphs):
        raise ValueError("cannot coalesce graphs of different models: "
                         f"n_layers {[g.n_layers for g in graphs]}")
    if offsets is None:
        offsets = list(np.cumsum([0] + [g.n_requests for g in graphs])[:-1])
    if len(offsets) != len(graphs):
        raise ValueError(f"{len(offsets)} offsets for {len(graphs)} graphs")

    by_key: dict[tuple[int, int, int], list[int]] = {}
    transfers: list[Transfer] = []
    rows: list[int] = []
    for g, off in zip(graphs, offsets):
        off = int(off)
        for t in g.tasks:
            by_key.setdefault(t.key, []).extend(r + off for r in t.requests)
        transfers.extend(dataclasses.replace(tr, request=tr.request + off)
                         for tr in g.transfers)
        rows.extend(r + off for r in g.requests)

    tasks = tuple(StageTask(n, s, e, tuple(sorted(rs)))
                  for (n, s, e), rs in sorted(by_key.items(),
                                              key=lambda kv: (kv[0][1],
                                                              kv[0][0])))
    n_requests = max(int(off) + g.n_requests
                     for g, off in zip(graphs, offsets))
    return StageGraph(tasks, tuple(transfers), n_layers, n_requests,
                      tuple(rows))


def stage_signature(graph: StageGraph) -> tuple[tuple[int, int], ...]:
    """The unique ``(layer_start, layer_end)`` ranges a graph executes —
    the jit-compilation footprint (one closure per range)."""
    return tuple(sorted({(t.layer_start, t.layer_end) for t in graph.tasks}))


def trace_args(graph: StageGraph) -> dict:
    """Summarize a graph for a rich (dict-args) trace span — the low-rate
    annotation the CLI attaches to its per-round execution span, so a
    Perfetto click on the round shows what actually launched."""
    return {"n_tasks": len(graph.tasks),
            "n_transfers": len(graph.transfers),
            "n_requests": len(graph.requests),
            "n_shared": graph.n_shared,
            "transfer_bytes": float(sum(tr.nbytes for tr in graph.transfers)),
            "signature": [list(rng) for rng in stage_signature(graph)]}


def link_payload_bytes(graph: StageGraph) -> dict[tuple[int, int], float]:
    """Total modeled bytes each directed link carries for this graph — the
    coverage map of a comm calibration: links listed here are the ones a
    byte-moving transport will sample when the graph executes."""
    out: dict[tuple[int, int], float] = {}
    for tr in graph.transfers:
        key = (tr.src_node, tr.dst_node)
        out[key] = out.get(key, 0.0) + tr.nbytes
    return out
