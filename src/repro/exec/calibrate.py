"""Measured-latency profile calibration — closing the predicted ↔ realized
loop (DESIGN.md §5).

The analytic profiles (:mod:`repro.core.profiles`) predict a layer's compute
time as ``c_j / speed_i`` from FLOP counts; DroNet-style measurements show
realized kernel time is dominated by effects the FLOP model cannot see
(cache behavior, im2col overheads, BLAS efficiency).  This module turns an
:class:`~repro.exec.engine.ExecutionReport` back into profile updates:

* :func:`measured_layer_seconds` — distribute each measured stage wall over
  its layers proportionally to the analytic compute vector (min over
  launches for noise robustness), yielding a per-layer measured time;
* :func:`calibrate_profile` — a new :class:`ModelProfile` whose compute
  vector reproduces the measured times at the nominal ``speed`` (so
  ``c_j' / speed == measured_j``): every registered planner consumes it
  unchanged, and Eq. 5 occupancy stays in consistent units;
* :func:`reconcile` — the analytic-vs-measured gap, per layer and per link
  (modeled delay vs the measured transport hop), plus the per-request MAE
  that the acceptance gate tracks across a calibrated re-solve;
* :func:`calibrate_rates` / the ``transport=`` arm of
  :func:`calibrated_problem` — the comm-side twin: a byte-moving transport
  backend (:mod:`repro.transport`) accumulates realized seconds/byte per
  directed link; sampled links replace the analytic rates, the problem's
  ``comm_source`` provenance records which transport priced them, and any
  registry planner re-solves on realized comm exactly as it re-solves on
  realized compute.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.ould import Problem
from ..core.profiles import ModelProfile
from .engine import ExecutionReport


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Analytic-vs-measured reconciliation for one executed plan."""

    layer_predicted_s: np.ndarray    # (M,) c_j / nominal speed
    layer_measured_s: np.ndarray     # (M,) from stage walls (predicted where
                                     #      no launch covered the layer)
    layer_covered: np.ndarray        # (M,) bool — measured by some launch
    link_modeled_s: dict             # (src, dst) → mean modeled delay
    link_serialize_s: dict           # (src, dst) → mean measured hop wall
    request_mae_s: float             # MAE(predicted, executed) per request
    profile: ModelProfile            # calibrated profile (compute updated)
    speed_scale: float               # nominal time / measured time (>1 ⇒
                                     #   hardware beats the FLOP model)
    # Comm-side twin (populated when a transport carried the transfers).
    link_measured_spb: dict = dataclasses.field(default_factory=dict)
                                     # (src, dst) → realized seconds/byte
    comm_mae_s: float = 0.0          # mean |modeled delay − realized hop|
                                     #   over executed transfers
    transport: str = "inproc"        # backend that produced the samples

    @property
    def layer_abs_gap_s(self) -> np.ndarray:
        return np.abs(self.layer_predicted_s - self.layer_measured_s)

    @property
    def link_abs_gap_s(self) -> dict:
        """Per directed link: |mean modeled delay − mean realized hop|."""
        return {k: abs(self.link_modeled_s[k] - self.link_serialize_s[k])
                for k in self.link_modeled_s if k in self.link_serialize_s}

    @property
    def mean_layer_gap_s(self) -> float:
        cov = self.layer_covered
        return float(self.layer_abs_gap_s[cov].mean()) if cov.any() else 0.0

    def summary(self) -> str:
        n_cov = int(self.layer_covered.sum())
        comm = ""
        if self.link_measured_spb:
            comm = (f", comm[{self.transport}]: "
                    f"{len(self.link_measured_spb)} links sampled, "
                    f"MAE={self.comm_mae_s * 1e3:.3f}ms")
        return (f"calibration: {n_cov}/{self.layer_covered.size} layers "
                f"measured, mean |gap|={self.mean_layer_gap_s * 1e3:.3f}ms, "
                f"request MAE={self.request_mae_s * 1e3:.3f}ms, "
                f"speed_scale={self.speed_scale:.3g}" + comm)


def measured_layer_seconds(report: ExecutionReport,
                           profile: ModelProfile) -> tuple[np.ndarray, np.ndarray]:
    """(M,) per-layer measured seconds + (M,) coverage mask.

    A stage launch measures the wall of its whole layer range on its whole
    batch; the per-layer estimate divides by the batch (these kernels scale
    ~linearly in batch on the target class of devices) and splits the range
    proportionally to the analytic compute vector — the standard profile-
    guided disaggregation.  Min over launches covering a layer.
    """
    comp = np.asarray(profile.compute_vector(), float)
    M = profile.num_layers
    measured = np.full(M, np.inf)
    for t in report.stage_timings:
        rng = slice(t.layer_start, t.layer_end)
        weights = comp[rng]
        total = weights.sum()
        share = (weights / total if total > 0
                 else np.full(t.layer_end - t.layer_start,
                              1.0 / (t.layer_end - t.layer_start)))
        per_item = t.wall_s / max(t.batch, 1)
        est = per_item * share
        measured[rng] = np.minimum(measured[rng], est)
    covered = np.isfinite(measured)
    measured = np.where(covered, measured, 0.0)
    return measured, covered


def calibrate_profile(profile: ModelProfile, layer_s: np.ndarray, *,
                      speed: float,
                      covered: np.ndarray | None = None) -> ModelProfile:
    """Profile whose compute vector realizes ``layer_s`` at ``speed``
    (uncovered layers keep their analytic FLOPs)."""
    layers = []
    for j, ly in enumerate(profile.layers):
        if covered is not None and not covered[j]:
            layers.append(ly)
            continue
        layers.append(dataclasses.replace(
            ly, compute_flops=float(layer_s[j] * speed)))
    return ModelProfile(profile.name, tuple(layers), profile.input_bytes)


def calibrate_rates(problem: Problem, link_spb: dict, *,
                    source: str = "measured") -> Problem:
    """Substitute realized per-link bandwidth into the instance's rates.

    ``link_spb`` maps ``(src, dst)`` to realized seconds/byte (a
    transport's :meth:`link_seconds_per_byte`).  Sampled links get the rate
    whose priced :meth:`~repro.core.ould.Problem.transfer_cost` reproduces
    the measurement exactly (horizon stacks spread it evenly over steps,
    since pricing sums them); unsampled links keep their analytic rates.
    ``comm_source`` records the provenance — it rides into ``Plan.problem``
    on the re-solve.
    """
    rates = np.array(problem.rates, float, copy=True)
    unit = (1.0 / problem.rate_unit_bytes) * problem.horizon()
    for (s, d), spb in link_spb.items():
        if s == d or not np.isfinite(spb) or spb <= 0:
            continue
        if s < rates.shape[-2] and d < rates.shape[-1]:
            rates[..., s, d] = unit / spb
    return dataclasses.replace(problem, rates=rates, comm_source=source)


def calibrated_problem(problem: Problem, report: ExecutionReport, *,
                       transport=None) -> tuple[Problem, "CalibrationReport"]:
    """The same instance with the profile calibrated from ``report`` —
    hand it straight back to any registered planner for the measured-cost
    re-solve.  Also returns the reconciliation.

    With ``transport`` (the backend that carried the report's transfers),
    the comm side calibrates too: every link the transport sampled gets its
    realized bandwidth substituted via :func:`calibrate_rates`, so the
    re-solve prices both compute AND comm on measured numbers."""
    recon = reconcile(problem, report, transport=transport)
    out = dataclasses.replace(problem, profile=recon.profile)
    if transport is not None and recon.link_measured_spb:
        out = calibrate_rates(out, recon.link_measured_spb,
                              source=f"measured:{recon.transport}")
    return out, recon


def _nominal_speed(problem: Problem) -> float:
    speed = problem.compute_speed
    if speed is None:
        return float("inf")
    finite = np.asarray(speed, float)
    finite = finite[np.isfinite(finite) & (finite > 0)]
    return float(finite.mean()) if finite.size else float("inf")


def reconcile(problem: Problem, report: ExecutionReport, *,
              transport=None) -> CalibrationReport:
    """Quantify the analytic-vs-measured gap per layer and per link, and
    build the calibrated profile.  ``transport`` adds the comm-side twin:
    realized per-link seconds/byte and the modeled-vs-realized comm MAE."""
    profile = problem.profile
    speed = _nominal_speed(problem)
    comp = np.asarray(profile.compute_vector(), float)
    predicted = comp / speed if np.isfinite(speed) else np.zeros_like(comp)

    measured, covered = measured_layer_seconds(report, profile)
    cal_speed = speed if np.isfinite(speed) else 1e9
    cal_profile = calibrate_profile(profile, measured, speed=cal_speed,
                                    covered=covered)

    link_modeled: dict[tuple[int, int], list[float]] = {}
    link_serial: dict[tuple[int, int], list[float]] = {}
    for tr in report.transfers:
        key = (tr.src_node, tr.dst_node)
        link_modeled.setdefault(key, []).append(tr.delay_s)
        link_serial.setdefault(key, []).append(tr.serialize_s)

    if report.predicted_s is not None:
        mae = float(report.abs_error_s[list(report.outputs)].mean()) \
            if report.outputs else 0.0
    else:
        mask = np.isfinite(report.executed_s)
        pred = (predicted.sum() + report.comm_s)
        mae = float(np.abs(pred[mask] - report.executed_s[mask]).mean()) \
            if mask.any() else 0.0

    pred_cov = predicted[covered].sum()
    meas_cov = measured[covered].sum()
    scale = float(pred_cov / meas_cov) if meas_cov > 0 and pred_cov > 0 else 1.0

    link_spb: dict[tuple[int, int], float] = {}
    comm_mae = 0.0
    tname = report.transport
    if transport is not None:
        link_spb = transport.link_seconds_per_byte()
        tname = transport.name
    if report.transfers:
        comm_mae = float(np.mean([abs(tr.delay_s - tr.serialize_s)
                                  for tr in report.transfers]))
    return CalibrationReport(
        predicted, measured, covered,
        {k: float(np.mean(v)) for k, v in link_modeled.items()},
        {k: float(np.mean(v)) for k, v in link_serial.items()},
        mae, cal_profile, scale,
        link_measured_spb=link_spb, comm_mae_s=comm_mae, transport=tname)
