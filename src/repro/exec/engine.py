"""StageGraph executor: jitted layer-range closures with per-stage and
per-transfer wall-clock accounting.

The engine runs a compiled :class:`~repro.exec.stage_graph.StageGraph` tick
by tick in topological order:

* each :class:`StageTask` executes as ONE jitted ``apply_layers`` closure —
  requests sharing the stage are stacked into a batch, so a hotspot plan
  compiles a handful of closures no matter how many requests ride them.
  Closures are cached per ``(layer_start, layer_end)`` range; model layers
  that route through :mod:`repro.kernels` pick up Pallas kernels on TPU and
  the jnp reference paths elsewhere.  With a ``mesh``, divisible batches are
  sharded across its devices (the CPU-device-count mesh CI forces via
  ``--xla_force_host_platform_device_count``);
* each boundary :class:`Transfer` is routed through the engine's
  :class:`~repro.transport.Transport` backend.  The default
  ``InProcTransport`` reproduces the pre-transport path bit-for-bit: the
  analytic link delay (``Problem.transfer_cost()`` — the exact coefficient
  OULD minimized) plus the *measured* host serialization wall.  The
  ``loopback`` / ``multiproc`` backends move the real activation bytes
  through worker OS processes and hand the consuming stage the
  reconstructed tensor, so the measured hop wall is a realized link sample
  (per-link bandwidth accumulates on the transport for comm calibration).

``executed latency`` of a request = measured stage walls along its path +
modeled link delays — the realized counterpart of
``Evaluation.per_request_s`` (LLHR-style: judge placements on realized, not
modeled, stage times).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.profiles import ModelProfile
from ..models import cnn
from ..obs import ENGINE, NULL_TRACER
from ..transport import InProcTransport, Transport
from .stage_graph import StageGraph, StageTask


@dataclasses.dataclass(frozen=True)
class StageTiming:
    """Measured execution of one batched stage launch."""

    node: int
    layer_start: int
    layer_end: int
    batch: int            # requests stacked into the launch
    wall_s: float         # measured kernel wall (post-compile, blocked)


@dataclasses.dataclass(frozen=True)
class TransferRecord:
    """One executed boundary shipment: modeled link delay + measured host
    serialization wall (device sync + copy of the activation buffer)."""

    request: int
    src_node: int
    dst_node: int
    layer: int
    nbytes: float
    delay_s: float        # modeled: nbytes × spb[src, dst]
    serialize_s: float    # measured: the transport hop wall (host
                          #   materialization for inproc; serialize + socket
                          #   round trip + reconstruct for loopback/multiproc)


@dataclasses.dataclass(frozen=True)
class ExecutionReport:
    """What actually ran: outputs plus the measured/modeled decomposition."""

    outputs: dict[int, np.ndarray]          # request row → final activation
    stage_timings: tuple[StageTiming, ...]
    transfers: tuple[TransferRecord, ...]
    executed_s: np.ndarray                  # (R,) measured comp + modeled comm
    compute_s: np.ndarray                   # (R,) measured stage walls only
    comm_s: np.ndarray                      # (R,) modeled link delays only
    predicted_s: np.ndarray | None = None   # (R,) analytic, when supplied
    transport: str = "inproc"               # backend that carried transfers

    def stage_wall(self, layer_start: int, layer_end: int) -> float:
        """Min measured wall over launches of this layer range."""
        walls = [t.wall_s for t in self.stage_timings
                 if (t.layer_start, t.layer_end) == (layer_start, layer_end)]
        if not walls:
            raise KeyError(f"no launch executed layers "
                           f"[{layer_start}, {layer_end})")
        return min(walls)

    @property
    def abs_error_s(self) -> np.ndarray:
        """|predicted − executed| per admitted request (requires predicted)."""
        assert self.predicted_s is not None, "report carries no prediction"
        mask = np.isfinite(self.executed_s) & np.isfinite(self.predicted_s)
        return np.abs(np.where(mask, self.predicted_s - self.executed_s, 0.0))


def layer_fns_for(profile: ModelProfile, params=None,
                  key=None) -> list[Callable]:
    """Per-unit apply functions matching ``profile``'s placement units.

    Supports the paper's CNN workloads (``lenet`` / ``vgg16``); other
    profiles must hand the engine their own ``layer_fns``.  ``params`` wins
    over ``key`` (fresh init).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    if profile.name == "lenet":
        params = params if params is not None else cnn.lenet_init(key)
        fns = cnn.lenet_layers(params)
    elif profile.name == "vgg16":
        params = params if params is not None else cnn.vgg16_init(key)
        fns = cnn.vgg16_layers(params)
    else:
        raise ValueError(
            f"no builtin layer fns for profile {profile.name!r}; "
            "pass layer_fns to ExecutionEngine directly")
    assert len(fns) == profile.num_layers
    return fns


class ExecutionEngine:
    """Executes stage graphs over one model's ``layer_fns``.

    One engine instance owns the jit cache, so repeated runs (the swarm
    simulator's per-epoch sampling, calibration re-measures) pay compilation
    once per unique layer range.
    """

    def __init__(self, layer_fns: Sequence[Callable], *, mesh=None,
                 data_axis: str = "data",
                 transport: Transport | None = None, tracer=None):
        self.layer_fns = list(layer_fns)
        self.mesh = mesh
        self.data_axis = data_axis
        self.transport = transport if transport is not None else InProcTransport()
        # Observability: engine spans are real-time (``tracer.now()``) and
        # reconstructed from the measured walls the engine takes anyway —
        # nothing is timed inside the jitted closures.  Transfer spans come
        # from the transport itself (single emission point in _record).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled:
            self.tracer.intern("stage", "batch", "n_layers")
            self.tracer.intern("stage_measure", "layer_start", "layer_end")
            self.tracer.intern("warm_start", "n_ranges")
            set_tr = getattr(self.transport, "set_tracer", None)
            if set_tr is not None:
                set_tr(self.tracer)
        self._closures: dict[tuple[int, int], Callable] = {}
        self._warm: set[tuple[int, int, tuple]] = set()

    # -- jit cache -----------------------------------------------------------
    def closure(self, layer_start: int, layer_end: int) -> Callable:
        rng = (layer_start, layer_end)
        if rng not in self._closures:
            fns = self.layer_fns

            @jax.jit
            def _run(x, _s=layer_start, _e=layer_end):
                return cnn.apply_layers(fns, x, _s, _e)

            self._closures[rng] = _run
        return self._closures[rng]

    def _device_put(self, x: jax.Array) -> jax.Array:
        """Shard the batch dim over the mesh when it divides evenly."""
        if self.mesh is None:
            return x
        n = self.mesh.shape.get(self.data_axis, 1)
        if n <= 1 or x.shape[0] % n != 0:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(self.data_axis, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def measure_range(self, layer_start: int, layer_end: int, x, *,
                      repeats: int = 1) -> float:
        """Measured wall of layers [layer_start, layer_end) on ``x`` (min of
        ``repeats``, compile excluded) — the swarm simulator's executed-
        latency sample for a stage."""
        fn = self.closure(layer_start, layer_end)
        x = self._device_put(jnp.asarray(x))
        warm_key = (layer_start, layer_end, tuple(x.shape))
        if warm_key not in self._warm:
            jax.block_until_ready(fn(x))
            self._warm.add(warm_key)
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        if self.tracer.enabled:
            self.tracer.span(ENGINE, "stage_measure",
                             self.tracer.now() - best, best,
                             a0=layer_start, a1=layer_end)
        return best

    def warm_start(self, signature: Sequence[tuple[int, int]],
                   frame: np.ndarray) -> float:
        """Pre-compile the closures of a stage signature (the ``(start, end)``
        ranges of :func:`~repro.exec.stage_graph.stage_signature`) on one
        sample frame; returns the total wall.

        This is the churn-rejoin path: with the persistent compilation cache
        enabled (:mod:`repro.exec.compile_cache`) a node that joins
        mid-scenario replays compiles as disk-cache hits — milliseconds
        instead of fresh XLA compiles.  Boundary activations are propagated
        through the signature itself; a range whose start no prior range
        produced is fed through a ``[0, start)`` prefix closure.
        """
        t_begin = time.perf_counter()
        acts: dict[int, jax.Array] = {0: jnp.asarray(frame[None])}
        for s, e in sorted(signature):
            if s not in acts:
                acts[s] = self.closure(0, s)(acts[0])
            acts[e] = jax.block_until_ready(self.closure(s, e)(acts[s]))
            self._warm.add((s, e, tuple(acts[s].shape)))
        wall = time.perf_counter() - t_begin
        if self.tracer.enabled:
            self.tracer.span(ENGINE, "warm_start",
                             self.tracer.now() - wall, wall,
                             a0=len(signature))
        return wall

    def _launch(self, task: StageTask, x: jax.Array) -> tuple[jax.Array, float]:
        """Run one batched stage; returns (output, measured wall seconds)."""
        fn = self.closure(task.layer_start, task.layer_end)
        x = self._device_put(x)
        warm_key = (task.layer_start, task.layer_end, tuple(x.shape))
        if warm_key not in self._warm:        # compile outside the clock
            jax.block_until_ready(fn(x))
            self._warm.add(warm_key)
        t0 = time.perf_counter()
        y = jax.block_until_ready(fn(x))
        return y, time.perf_counter() - t0

    # -- execution -----------------------------------------------------------
    def run(self, graph: StageGraph, frames: np.ndarray, *,
            predicted_s: np.ndarray | None = None) -> ExecutionReport:
        """Execute ``graph`` on ``frames`` (one leading row per plan request;
        rejected rows are never read).  Returns the full measured report."""
        acts: dict[int, jax.Array] = {
            r: jnp.asarray(frames[r][None]) for r in graph.requests}
        timings: list[StageTiming] = []
        compute_s = np.zeros(graph.n_requests)

        transfer_by_consumer = {(tr.request, tr.layer): tr
                                for tr in graph.transfers}
        records: list[TransferRecord] = []

        for task in graph.tasks:
            # Boundary shipments INTO this stage ride the transport backend:
            # inproc measures the host serialization of the inbound
            # activation; loopback/multiproc move its bytes to the worker
            # process owning the destination node and the consuming stage
            # reads what came back.
            for r in task.requests:
                tr = transfer_by_consumer.get((r, task.layer_start))
                if tr is None:
                    continue
                res = self.transport.ship(tr.src_node, tr.dst_node, acts[r])
                acts[r] = res.array
                records.append(TransferRecord(
                    tr.request, tr.src_node, tr.dst_node, tr.layer,
                    tr.nbytes, tr.delay_s, res.wall_s))
            x = (acts[task.requests[0]] if len(task.requests) == 1
                 else jnp.concatenate([acts[r] for r in task.requests]))
            y, wall = self._launch(task, x)
            timings.append(StageTiming(task.node, task.layer_start,
                                       task.layer_end, len(task.requests),
                                       wall))
            if self.tracer.enabled:
                # ts backdated by the measured wall so the span covers the
                # timed run, never the compile _launch keeps off the clock.
                self.tracer.span(ENGINE, "stage",
                                 self.tracer.now() - wall, wall,
                                 lane=task.node, a0=len(task.requests),
                                 a1=task.layer_end - task.layer_start)
            for b, r in enumerate(task.requests):
                acts[r] = y[b][None]
                compute_s[r] += wall

        comm_s = np.zeros(graph.n_requests)
        for tr in graph.transfers:
            comm_s[tr.request] += tr.delay_s
        executed = np.full(graph.n_requests, np.inf)
        for r in graph.requests:
            executed[r] = compute_s[r] + comm_s[r]
        outputs = {r: np.asarray(acts[r][0]) for r in graph.requests}
        return ExecutionReport(outputs, tuple(timings), tuple(records),
                               executed, compute_s, comm_s, predicted_s,
                               transport=self.transport.name)

    def sequential_reference(self, frames: np.ndarray,
                             requests: Sequence[int]) -> dict[int, np.ndarray]:
        """Ground truth: every admitted request through all layers, one node."""
        fn = self.closure(0, len(self.layer_fns))
        return {r: np.asarray(fn(jnp.asarray(frames[r][None]))[0])
                for r in requests}
