"""Paper Fig. 8: OULD vs the three heuristics (Nearest / HRM / Nearest-HRM)
on a single fixed-snapshot configuration.

Claims: OULD latency ≤ every heuristic at every load (it is the optimum);
Nearest beats the memory-driven heuristics (air-to-air rates dominate)."""

from __future__ import annotations

import numpy as np

from repro.core import evaluate, solve_heuristic, solve_ould

from .common import HIGH_MEM, Csv, snapshot_problem, timed


def run(csv: Csv) -> dict:
    loads = [2, 6, 10, 14]
    methods = ["ould", "nearest", "hrm", "nearest_hrm"]
    res = {m: {"lat": [], "shared": []} for m in methods}
    optimal_everywhere = True
    nearest_wins = 0
    for r in loads:
        prob = snapshot_problem("lenet", 12, r, mem=HIGH_MEM, seed=3)
        evs = {}
        for m in methods:
            if m == "ould":
                sol, us = timed(solve_ould, prob, mip_rel_gap=1e-4,
                                time_limit=30.0)
            else:
                sol, us = timed(solve_heuristic, prob, m)
            ev = evaluate(prob, sol)
            evs[m] = ev
            res[m]["lat"].append(ev.avg_latency_per_request)
            res[m]["shared"].append(ev.shared_bytes / 1e6)
            csv.add(f"heuristics/{m}/R{r}", us,
                    f"lat={ev.avg_latency_per_request:.4f}s "
                    f"adm={ev.n_admitted}")
        full = [m for m in methods if evs[m].n_admitted == r]
        if "ould" in full:
            for m in full:
                if evs[m].avg_latency_per_request < \
                        evs["ould"].avg_latency_per_request - 1e-9:
                    optimal_everywhere = False
        if ("nearest" in full and "hrm" in full and
                evs["nearest"].avg_latency_per_request
                <= evs["hrm"].avg_latency_per_request + 1e-12):
            nearest_wins += 1
    csv.add("heuristics/claims", 0.0,
            f"OULD_is_optimal={optimal_everywhere} "
            f"nearest<=hrm_in_{nearest_wins}_of_{len(loads)}")
    return res
