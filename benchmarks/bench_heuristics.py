"""Paper Fig. 8: OULD vs the three heuristics (Nearest / HRM / Nearest-HRM)
on a single fixed-snapshot configuration — pure iteration over the planner
registry; no method-specific call signatures.

Claims: OULD latency ≤ every heuristic at every load (it is the optimum);
Nearest beats the memory-driven heuristics (air-to-air rates dominate)."""

from __future__ import annotations

from repro.core import SnapshotView, get_planner

from .common import HIGH_MEM, Csv, snapshot_problem, timed

METHODS = ("ould-ilp", "nearest", "hrm", "nearest-hrm")


def run(csv: Csv) -> dict:
    loads = [2, 6, 10, 14]
    # One option dict configures the whole sweep; heuristics ignore the
    # ILP tolerances they don't consume.
    planners = {m: get_planner(m, mip_rel_gap=1e-4, time_limit=30.0)
                for m in METHODS}
    res = {m: {"lat": [], "shared": []} for m in METHODS}
    optimal_everywhere = True
    nearest_wins = 0
    for r in loads:
        prob = snapshot_problem("lenet", 12, r, mem=HIGH_MEM, seed=3)
        evs = {}
        for m, planner in planners.items():
            plan, us = timed(planner.plan, prob, SnapshotView(prob.rates))
            ev = plan.evaluate()
            evs[m] = ev
            res[m]["lat"].append(ev.avg_latency_per_request)
            res[m]["shared"].append(ev.shared_bytes / 1e6)
            csv.add(f"heuristics/{plan.planner_name}/R{r}", us,
                    f"lat={ev.avg_latency_per_request:.4f}s "
                    f"adm={ev.n_admitted}")
        full = [m for m in METHODS if evs[m].n_admitted == r]
        if "ould-ilp" in full:
            for m in full:
                if evs[m].avg_latency_per_request < \
                        evs["ould-ilp"].avg_latency_per_request - 1e-9:
                    optimal_everywhere = False
        if ("nearest" in full and "hrm" in full and
                evs["nearest"].avg_latency_per_request
                <= evs["hrm"].avg_latency_per_request + 1e-12):
            nearest_wins += 1
    csv.add("heuristics/claims", 0.0,
            f"OULD_is_optimal={optimal_everywhere} "
            f"nearest<=hrm_in_{nearest_wins}_of_{len(loads)}")
    return res
