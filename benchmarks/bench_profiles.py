"""Paper Fig. 3 + Table I: per-layer inference memory footprints (LeNet,
VGG-16 at 595×326 RGB) and the parameter counts of Table I's architectures.

Claim: the VGG-16 total footprint exceeds any single 256/512 MB node (the
motivation for distribution), LeNet's does not."""

from __future__ import annotations

from repro.core import lenet_profile, vgg16_profile

from .common import HIGH_MEM, Csv


def run(csv: Csv) -> dict:
    res = {}
    for name, prof in (("lenet", lenet_profile()), ("vgg16", vgg16_profile())):
        per_layer = [ly.memory_bytes / 1e6 for ly in prof.layers]
        res[name] = per_layer
        csv.add(f"profiles/{name}", 0.0,
                f"M={prof.num_layers} total={prof.total_memory / 1e6:.0f}MB "
                f"flops={prof.total_flops / 1e9:.1f}GF "
                f"max_layer={max(per_layer):.0f}MB")
    vgg_needs_dist = sum(res["vgg16"]) * 1e6 > HIGH_MEM
    lenet_fits = sum(res["lenet"]) * 1e6 < HIGH_MEM
    csv.add("profiles/claims", 0.0,
            f"vgg_exceeds_single_node={vgg_needs_dist} "
            f"lenet_fits_single_node={lenet_fits}")
    return res
