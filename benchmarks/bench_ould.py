"""Paper Fig. 4–7: OULD latency per request + shared data vs incoming load,
varying network density N ∈ {10, 15} and memory level {256, 512} MB, for
LeNet and VGG-16.

Claims validated (EXPERIMENTS.md §Reproduction):
  C1  low LeNet loads are served locally (zero shared data);
  C2  capacity (max parallel requests) grows with N and with memory;
  C3  latency grows with load once distribution kicks in;
  C4  VGG always distributes (no single node fits it) and moves more data;
  C5  low-memory networks exchange more data per admitted request.
"""

from __future__ import annotations

from repro.core import SnapshotView, get_planner

from .common import HIGH_MEM, LOW_MEM, Csv, snapshot_problem, timed


def sweep(csv: Csv, model: str, n_uavs: int, mem: float, loads: list[int],
          planner_name: str = "ould-ilp") -> dict:
    tag = f"{model}_N{n_uavs}_{'hi' if mem == HIGH_MEM else 'lo'}mem"
    out = {"load": [], "avg_latency": [], "shared_mb": [], "admitted": []}
    planner = get_planner(planner_name, mip_rel_gap=1e-3, time_limit=45.0)
    for r in loads:
        prob = snapshot_problem(model, n_uavs, r, mem=mem)
        plan, us = timed(planner.plan, prob, SnapshotView(prob.rates))
        ev = plan.evaluate()
        out["load"].append(r)
        out["avg_latency"].append(ev.avg_latency_per_request)
        out["shared_mb"].append(ev.shared_bytes / 1e6)
        out["admitted"].append(ev.n_admitted)
        csv.add(f"ould/{tag}/R{r}", us,
                f"lat={ev.avg_latency_per_request:.3f}s "
                f"shared={ev.shared_bytes / 1e6:.1f}MB adm={ev.n_admitted}")
        assert ev.feasible, (tag, r)
    return out


def run(csv: Csv) -> dict:
    res = {}
    res["lenet_10_hi"] = sweep(csv, "lenet", 10, HIGH_MEM, [2, 6, 10, 14, 18])
    res["lenet_10_lo"] = sweep(csv, "lenet", 10, LOW_MEM, [2, 6, 10, 14])
    res["lenet_15_hi"] = sweep(csv, "lenet", 15, HIGH_MEM, [2, 10, 18, 25])
    # VGG is compute-bound per node (117 GF > 95 GF budget) — the exact ILP
    # is required to find split placements (DP admission is conservative)
    res["vgg16_10_hi"] = sweep(csv, "vgg16", 10, HIGH_MEM, [1, 2, 3])
    res["vgg16_10_lo"] = sweep(csv, "vgg16", 10, LOW_MEM, [1, 2])
    res["vgg16_15_hi"] = sweep(csv, "vgg16", 15, HIGH_MEM, [1, 3, 5])

    # paper-claim checks
    c1 = res["lenet_10_hi"]["shared_mb"][0] < 0.05
    cap_n = res["lenet_15_hi"]["admitted"][-1] >= res["lenet_10_hi"]["admitted"][-1]
    cap_m = res["lenet_10_hi"]["admitted"][-1] >= res["lenet_10_lo"]["admitted"][-1]
    lat_up = (res["lenet_10_hi"]["avg_latency"][-1]
              >= res["lenet_10_hi"]["avg_latency"][0] - 1e-9)
    ok_hi = [s for s, a in zip(res["vgg16_10_hi"]["shared_mb"],
                           res["vgg16_10_hi"]["admitted"]) if a]
    vgg_dist = bool(ok_hi) and min(ok_hi) > 0.0
    csv.add("ould/claims", 0.0,
            f"C1_local_lowload={c1} C2a_capacity_N={cap_n} "
            f"C2b_capacity_mem={cap_m} C3_latency_load={lat_up} "
            f"C4_vgg_distributes={vgg_dist}")
    return res
