"""Shared benchmark scaffolding: paper-calibrated node parameters, topology
builders, CSV emission.

Calibration (paper §IV): Raspberry-Pi-class UAVs, B=20 MHz, memory levels
{256, 512} MB, compute 9.5 GFLOPS.  Capacity constraints are occupancy per
decision period (we use a 10 s window ⇒ 95 GFLOP compute budget per node).
Workloads: LeNet (M=7) and VGG-16 (M=18) on 595×326 RGB frames.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Problem, RadioParams, RPGMobility, RPGParams,
                        lenet_profile, rate_matrix, vgg16_profile)

MB = 1e6
HIGH_MEM = 512 * MB
LOW_MEM = 256 * MB
GFLOPS = 9.5e9             # per-node compute speed (paper)
PERIOD_S = 10.0            # decision window for the occupancy budget
COMP_CAP = GFLOPS * PERIOD_S
RADIO = RadioParams()

PROFILES = {
    "lenet": lenet_profile(),
    "vgg16": vgg16_profile(),
}


def make_network(n_uavs: int, area_m: float, seed: int = 0,
                 homogeneous: bool = True) -> RPGMobility:
    return RPGMobility(RPGParams(n_uavs=n_uavs, area_m=area_m,
                                 homogeneous=homogeneous), seed=seed)


def snapshot_problem(model: str, n_uavs: int, requests: int, *,
                     mem: float = HIGH_MEM, area: float = 100.0,
                     seed: int = 0, hotspots: int = 3) -> Problem:
    """Static single-snapshot OULD instance (paper §IV-A setting).

    Requests originate at a few *hotspot* UAVs (the ones over the incident),
    which is what makes distribution necessary: the data-generating nodes
    saturate first while the rest of the swarm has idle capacity."""
    mob = make_network(n_uavs, area, seed)
    pos = mob.positions(1, seed=seed)[0]
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, min(hotspots, n_uavs), requests).astype(np.int64)
    return Problem(
        profile=PROFILES[model],
        mem_cap=np.full(n_uavs, mem),
        comp_cap=np.full(n_uavs, COMP_CAP),
        rates=rate_matrix(pos, RADIO),
        sources=sources,
        compute_speed=np.full(n_uavs, GFLOPS),
    )


class Csv:
    """Collects `name,us_per_call,derived` rows (run.py contract)."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
