"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only ould,mp,...]

Prints ``name,us_per_call,derived`` CSV rows (collected via common.Csv) and
writes benchmarks/artifacts/results.json.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from .common import Csv  # noqa: E402

MODULES = ["profiles", "ould", "heuristics", "mp", "runtime",
           "tpu_placement", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    todo = args.only.split(",") if args.only else MODULES

    csv = Csv()
    print("name,us_per_call,derived")
    results: dict = {}
    for name in todo:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        try:
            results[name] = mod.run(csv)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            csv.add(f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}")
            results[name] = {"error": str(e)}
    out = pathlib.Path(__file__).resolve().parent / "artifacts" / "results.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
