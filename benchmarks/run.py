"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only ould,mp,...] [--quick]

``--quick`` runs a <60 s CPU smoke subset (make-free CI path): each selected
module's ``run(csv, quick=True)`` when it accepts the flag.

Prints ``name,us_per_call,derived`` CSV rows (collected via common.Csv) and
writes benchmarks/artifacts/results.json.
"""

from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from .common import Csv  # noqa: E402

MODULES = ["profiles", "ould", "heuristics", "mp", "swarm", "runtime",
           "exec", "tpu_placement", "roofline", "obs"]
QUICK_MODULES = ["profiles", "swarm", "exec", "obs"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--quick", action="store_true",
                    help="<60s CPU smoke subset (" + ",".join(QUICK_MODULES)
                         + " by default)")
    args = ap.parse_args()
    default = QUICK_MODULES if args.quick else MODULES
    todo = args.only.split(",") if args.only else default

    csv = Csv()
    print("name,us_per_call,derived")
    results: dict = {}
    for name in todo:
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            kw = {}
            if args.quick and "quick" in inspect.signature(mod.run).parameters:
                kw["quick"] = True
            results[name] = mod.run(csv, **kw)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            csv.add(f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}")
            results[name] = {"error": str(e)}
    out = pathlib.Path(__file__).resolve().parent / "artifacts" / "results.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
