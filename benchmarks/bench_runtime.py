"""Paper Fig. 14: solver runtime — OULD re-solved per time step vs OULD-MP
one-shot over the horizon, at 4 and 8 concurrent requests.  Both strategies
are registry planners: ``ould-mp`` plans once on the HorizonView; the
static-resolve baseline is ``ould-ilp`` planned on every step's snapshot.

Claim: OULD-MP runtime < T × (single OULD runtime), and the gap widens with
the horizon (the paper's §IV-C complexity argument)."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import HorizonView, Problem, SnapshotView, get_planner

from .common import COMP_CAP, GFLOPS, HIGH_MEM, PROFILES, Csv, make_network


def run(csv: Csv) -> dict:
    res = {}
    ok = True
    for requests in (4, 8):
        for horizon in (2, 4, 6, 8):
            mob = make_network(10, 200.0, seed=1, homogeneous=False)
            rng = np.random.default_rng(1)
            sources = rng.integers(0, 3, requests).astype(np.int64)  # hotspots
            rates = mob.predicted_rates(horizon)
            prob = Problem(PROFILES["lenet"], np.full(10, HIGH_MEM),
                           np.full(10, COMP_CAP), rates, sources,
                           compute_speed=np.full(10, GFLOPS))
            opts = dict(mip_rel_gap=1e-3, time_limit=20.0)

            t0 = time.perf_counter()
            get_planner("ould-mp", **opts).plan(prob, HorizonView(rates))
            mp_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            static = get_planner("ould-ilp", **opts)
            for t in range(horizon):     # re-plan on every step's snapshot
                static.plan(dataclasses.replace(prob, rates=rates[t]),
                            SnapshotView(rates[t]))
            st_s = time.perf_counter() - t0

            res[f"R{requests}_T{horizon}"] = (mp_s, st_s)
            ok &= mp_s <= st_s * 1.1
            csv.add(f"runtime/R{requests}_T{horizon}", mp_s * 1e6,
                    f"ould_mp={mp_s:.2f}s static_resolve={st_s:.2f}s "
                    f"speedup={st_s / max(mp_s, 1e-9):.2f}x")
    csv.add("runtime/claims", 0.0, f"mp_faster_than_resolve_everywhere={ok}")
    return res
