"""Paper Fig. 14: solver runtime — OULD re-solved per time step vs OULD-MP
one-shot over the horizon, at 4 and 8 concurrent requests.

Claim: OULD-MP runtime < T × (single OULD runtime), and the gap widens with
the horizon (the paper's §IV-C complexity argument)."""

from __future__ import annotations

import numpy as np

from repro.core import solve_ould_mp, solve_static_resolve

from .common import COMP_CAP, GFLOPS, HIGH_MEM, PROFILES, Csv, make_network


def run(csv: Csv) -> dict:
    res = {}
    ok = True
    for requests in (4, 8):
        for horizon in (2, 4, 6, 8):
            mob = make_network(10, 200.0, seed=1, homogeneous=False)
            rng = np.random.default_rng(1)
            sources = rng.integers(0, 3, requests).astype(np.int64)  # hotspots
            kw = dict(mem_cap=np.full(10, HIGH_MEM),
                      comp_cap=np.full(10, COMP_CAP), sources=sources,
                      mobility=mob, horizon=horizon,
                      compute_speed=np.full(10, GFLOPS),
                      mip_rel_gap=1e-3, time_limit=20.0)
            mp = solve_ould_mp(PROFILES["lenet"], **kw)
            st = solve_static_resolve(PROFILES["lenet"], **kw)
            res[f"R{requests}_T{horizon}"] = (mp.runtime_s, st.runtime_s)
            ok &= mp.runtime_s <= st.runtime_s * 1.1
            csv.add(f"runtime/R{requests}_T{horizon}",
                    mp.runtime_s * 1e6,
                    f"ould_mp={mp.runtime_s:.2f}s "
                    f"static_resolve={st.runtime_s:.2f}s "
                    f"speedup={st.runtime_s / max(mp.runtime_s, 1e-9):.2f}x")
    csv.add("runtime/claims", 0.0, f"mp_faster_than_resolve_everywhere={ok}")
    return res
