"""Plan-faithful execution engine (repro.exec): equivalence, dedup,
pipelined non-uniform cuts, and measured-latency calibration.

Claims:
  E1  the engine's output is numerically equivalent to sequential
      ``apply_layers`` for every plan in a fixed-seed scenario matrix —
      uniform and non-uniform cuts, several registered planners;
  E2  shared-stage dedup: hotspot requests with identical placements run as
      ONE batched launch per stage instead of one launch per request, with
      numerics pinned to the sequential reference.  The launch-count
      reduction (R× fewer dispatches — the real-swarm win, where each
      launch is a scheduling round-trip) is the exact lock; wall clock is
      reported as ungated ``_info`` metrics and is *not* claimed to improve
      here — on the forced 8-virtual-device CPU mesh the sharded batch pays
      collective overhead on shared physical cores and typically lands
      ~0.7–1× of the loop;
  E3  OULD's non-uniform stage cuts run *pipelined* with microbatches
      (``pipeline_forward_stages``, padded slices + validity mask) instead
      of falling back to sequential — correctness bool plus wall-clock on
      the stage mesh (CI forces an 8-device CPU mesh via
      ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; regenerate the
      baseline under the same flag for comparable stage counts);
  E4  calibration (measured stage walls → profile compute vectors) reduces
      predicted-vs-measured latency error on a re-solve — the ``improved``
      boolean is the lock (the analytic FLOP model is off by a large
      systematic factor, so the reduction survives timing noise); the
      magnitudes are ungated ``_info``;
  E5  cross-arrival stage batching (``coalesce_graphs``): requests that
      arrive in different admission rounds but share a stage coalesce into
      one launch — the launch-count reduction is exact, the coalesced
      outputs match the per-round executions within TOL (the queueing
      runtime's batching contract), walls are ungated ``_info``.

  E6  the byte-moving transport substrate (``repro.transport``) and the
      persistent compile cache: a loopback run routes every boundary
      activation through 2 worker OS processes and must be *bitwise* equal
      to the in-proc run (``loopback_exact`` + ``n_worker_processes`` are
      exact locks); realized per-link bandwidth feeds ``calibrate_rates``
      and the re-solve's modeled-vs-realized comm MAE must drop
      (``comm_improved`` exact, magnitudes ``_info``); recompiling after a
      simulated process restart hits the persistent cache —
      ``warm_start_speedup`` is the strict machine-relative lock (cold and
      warm walls are ``_info``).  E6 runs LAST: the restart simulation
      clears the in-memory jit cache, which would cold-start every other
      bench's warmed closures.

Metric naming follows check_regression's classes: measured walls and error
magnitudes end in ``_info`` (present, never value-gated); counts, stage
shapes, and correctness booleans are exact and must not move under the
pinned seeds.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Problem, SnapshotView, Solution, get_planner,
                        lenet_profile)
from repro.core.planner import Plan
from repro.core.radio import RadioParams, rate_matrix
from repro.exec import (ExecutionEngine, calibrated_problem, coalesce_graphs,
                        compile_plan, layer_fns_for)
from repro.parallel.pipeline import pipeline_forward_stages

from .common import MB, Csv, make_network

TOL = 1e-5
FRAME_HW = (326, 595, 3)


def _snapshot(n_uavs: int, requests: int, *, mem_mb: float, seed: int = 0,
              hotspots: int = 3, same_source: bool = False) -> Problem:
    mob = make_network(n_uavs, 150.0, seed=seed, homogeneous=False)
    rates = rate_matrix(mob.positions(1, seed=seed)[0], RadioParams())
    rng = np.random.default_rng(seed)
    sources = (np.zeros(requests, np.int64) if same_source
               else rng.integers(0, hotspots, requests).astype(np.int64))
    return Problem(lenet_profile(), np.full(n_uavs, mem_mb * MB),
                   np.full(n_uavs, 95e9), rates, sources,
                   compute_speed=np.full(n_uavs, 9.5e9))


def _manual_plan(prob: Problem, sizes: list[int]) -> Plan:
    """Every request on the same non-uniform cut, one node per stage."""
    M, R = prob.n_layers, prob.n_requests
    assign = np.zeros((R, M), np.int64)
    j = 0
    for node, size in enumerate(sizes):
        assign[:, j:j + size] = node
        j += size
    sol = Solution(assign, 0.0, "feasible", 0.0, np.ones(R, bool),
                   solver="manual")
    return Plan(sol, "manual", "snapshot", prob)


def _is_nonuniform(plan: Plan) -> bool:
    for r in range(plan.problem.n_requests):
        if not plan.admitted[r]:
            continue
        sizes = {s.layer_end - s.layer_start for s in plan.stages(r)}
        if len(sizes) > 1:
            return True
    return False


def _bench_equivalence(csv: Csv, engine: ExecutionEngine, quick: bool) -> dict:
    """E1: engine == sequential for every plan in the scenario matrix."""
    rng = np.random.default_rng(0)
    n_plans = n_nonuniform = 0
    worst = 0.0
    matrix = []
    prob = _snapshot(8, 5, mem_mb=128, seed=0)
    for name in (("ould-dp", "ould-dp-sparse", "nearest") if quick else
                 ("ould-dp", "ould-dp-sparse", "nearest", "hrm",
                  "nearest-hrm")):
        matrix.append((prob, get_planner(name).plan(
            prob, SnapshotView(prob.rates))))
    cut_prob = _snapshot(6, 2, mem_mb=4096, seed=1)
    for sizes in ([3, 4], [1, 4, 2], [2, 2, 1, 2]):
        matrix.append((cut_prob, _manual_plan(cut_prob, sizes)))

    for mprob, plan in matrix:
        graph = compile_plan(plan)
        if not graph.requests:
            continue
        n_plans += 1
        n_nonuniform += int(_is_nonuniform(plan))
        frames = rng.standard_normal(
            (mprob.n_requests, *FRAME_HW)).astype(np.float32)
        report = engine.run(graph, frames)
        ref = engine.sequential_reference(frames, graph.requests)
        worst = max(worst, max(np.abs(report.outputs[r] - ref[r]).max()
                               for r in graph.requests))
    ok = bool(worst < TOL)
    csv.add("exec/claims/E1_plan_faithful", 0.0,
            f"plans={n_plans} nonuniform={n_nonuniform} "
            f"max_err={worst:.2e} equivalent={ok}")
    assert ok, f"engine diverged from sequential reference: {worst}"
    return {"n_plans": n_plans, "n_nonuniform_cuts": n_nonuniform,
            "equivalent": ok}


def _bench_dedup(csv: Csv, engine: ExecutionEngine, quick: bool) -> dict:
    """E2: batched shared stages vs one-request-at-a-time execution.  The
    batch (8 requests) divides the forced 8-device mesh, so the batched
    launches run sharded across it (engine._device_put)."""
    requests = 8
    reps = 2 if quick else 3
    prob = _snapshot(6, requests, mem_mb=4096, seed=0, same_source=True)
    plan = _manual_plan(prob, [3, 4])      # all requests share both stages
    frames = np.random.default_rng(1).standard_normal(
        (requests, *FRAME_HW)).astype(np.float32)

    batched_graph = compile_plan(plan)
    solo_graphs = [compile_plan(plan, requests=[r]) for r in range(requests)]
    launches_loop = sum(len(g.tasks) for g in solo_graphs)

    # warm every shape; the mesh-sharded batched path must also stay
    # numerically faithful to the sequential reference
    batched_report = engine.run(batched_graph, frames)
    ref = engine.sequential_reference(frames, batched_graph.requests)
    sharded_ok = bool(max(np.abs(batched_report.outputs[r] - ref[r]).max()
                          for r in batched_graph.requests) < TOL)
    assert sharded_ok, "mesh-sharded batched execution diverged"
    for g in solo_graphs:
        engine.run(g, frames)
    t_batch = min(_timed(lambda: engine.run(batched_graph, frames))
                  for _ in range(reps))
    t_loop = min(_timed(lambda: [engine.run(g, frames) for g in solo_graphs])
                 for _ in range(reps))
    speedup = t_loop / max(t_batch, 1e-12)
    csv.add("exec/claims/E2_stage_dedup", t_batch * 1e6,
            f"R={requests} launches {launches_loop}->"
            f"{len(batched_graph.tasks)} loop={t_loop * 1e6:.0f}us "
            f"dedup_ratio={speedup:.2f}x")
    return {"requests": requests,
            "launches_batched": len(batched_graph.tasks),
            "launches_loop": launches_loop, "sharded_equivalent": sharded_ok,
            "batched_wall_info": t_batch, "loop_wall_info": t_loop,
            "dedup_ratio_info": speedup}


def _bench_coalesce(csv: Csv, engine: ExecutionEngine, quick: bool) -> dict:
    """E5: batch launches across arrival rounds.  Three admission rounds of
    the same hotspot cut (what a steady overload stream produces) execute as
    one graph; per-request outputs must match the per-round executions."""
    rounds_n, requests = 3, 4
    reps = 2 if quick else 3
    prob = _snapshot(6, requests, mem_mb=4096, seed=0, same_source=True)
    plan = _manual_plan(prob, [3, 4])
    graphs = [compile_plan(plan) for _ in range(rounds_n)]
    merged = coalesce_graphs(graphs)
    frames = np.random.default_rng(3).standard_normal(
        (rounds_n * requests, *FRAME_HW)).astype(np.float32)

    launches_rounds = sum(len(g.tasks) for g in graphs)
    launches_merged = len(merged.tasks)

    merged_report = engine.run(merged, frames)
    worst = 0.0
    for i, g in enumerate(graphs):
        solo = engine.run(g, frames[i * requests:(i + 1) * requests])
        for r in g.requests:
            worst = max(worst, float(np.abs(
                merged_report.outputs[r + i * requests]
                - solo.outputs[r]).max()))
    equivalent = bool(worst < TOL)
    t_merged = min(_timed(lambda: engine.run(merged, frames))
                   for _ in range(reps))
    t_rounds = min(_timed(lambda: [
        engine.run(g, frames[i * requests:(i + 1) * requests])
        for i, g in enumerate(graphs)]) for _ in range(reps))
    reduction = launches_rounds / max(launches_merged, 1)
    csv.add("exec/claims/E5_cross_arrival_batching", t_merged * 1e6,
            f"rounds={rounds_n} R={requests} launches {launches_rounds}->"
            f"{launches_merged} ({reduction:.1f}x) max_err={worst:.2e} "
            f"rounds_wall={t_rounds * 1e6:.0f}us equivalent={equivalent}")
    assert equivalent, f"E5: coalesced execution diverged: {worst}"
    assert launches_merged < launches_rounds, "E5: no launch reduction"
    return {"rounds": rounds_n, "requests_per_round": requests,
            "launches_rounds": launches_rounds,
            "launches_merged": launches_merged,
            "launch_reduction": reduction, "equivalent": equivalent,
            "merged_wall_info": t_merged, "rounds_wall_info": t_rounds}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _bench_pipeline(csv: Csv, quick: bool) -> dict:
    """E3: non-uniform cuts run pipelined on the stage mesh, matching the
    sequential reference (throughput reported, correctness asserted)."""
    from jax.sharding import Mesh

    devices = jax.devices()
    n_stages = max(1, min(4, len(devices)))
    mesh = Mesh(np.array(devices[:n_stages]), ("stage",))
    L, B, D = 8, 16, 192 if quick else 256
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def block_fn(w_l, h):
        return jnp.tanh(h @ w_l)

    sizes = {4: [1, 3, 2, 2], 2: [3, 5], 1: [8]}[n_stages]
    n_micro = 8

    @jax.jit
    def seq(w, x):
        def body(h, w_l):
            return block_fn(w_l, h), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    pipe = jax.jit(lambda w, x: pipeline_forward_stages(
        block_fn, w, x, mesh=mesh, stage_sizes=sizes, n_micro=n_micro))

    ref = jax.block_until_ready(seq(w, x))
    out = jax.block_until_ready(pipe(w, x))
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    matches = bool(err < TOL)
    reps = 3 if quick else 10
    t_seq = min(_timed(lambda: jax.block_until_ready(seq(w, x)))
                for _ in range(reps))
    t_pipe = min(_timed(lambda: jax.block_until_ready(pipe(w, x)))
                 for _ in range(reps))
    csv.add("exec/claims/E3_nonuniform_pipeline", t_pipe * 1e6,
            f"stages={sizes} micro={n_micro} err={err:.1e} "
            f"seq={t_seq * 1e6:.0f}us matches={matches}")
    assert matches, f"pipelined non-uniform cut diverged: {err}"
    return {"n_stages": n_stages, "stage_sizes": sizes, "n_micro": n_micro,
            "matches": matches, "pipeline_wall_info": t_pipe,
            "sequential_wall_info": t_seq}


def _bench_calibration(csv: Csv, engine: ExecutionEngine,
                       quick: bool) -> dict:
    """E4: predicted-vs-measured MAE before and after a calibrated re-solve."""
    prob = _snapshot(8, 4, mem_mb=128, seed=0)
    frames = np.random.default_rng(2).standard_normal(
        (4, *FRAME_HW)).astype(np.float32)
    planner = get_planner("ould-dp")

    plan = planner.plan(prob, SnapshotView(prob.rates))
    report = engine.run(
        compile_plan(plan), frames,
        predicted_s=np.asarray(plan.evaluate().per_request_s))
    mae_before = float(report.abs_error_s[list(report.outputs)].mean())

    cal_prob, recon = calibrated_problem(prob, report)
    replan = planner.plan(cal_prob, SnapshotView(prob.rates))
    rereport = engine.run(
        compile_plan(replan), frames,
        predicted_s=np.asarray(replan.evaluate().per_request_s))
    mae_after = float(rereport.abs_error_s[list(rereport.outputs)].mean())

    improved = bool(mae_after < mae_before)
    reduction = mae_before / max(mae_after, 1e-12)
    csv.add("exec/claims/E4_calibration", mae_after * 1e6,
            f"MAE {mae_before * 1e3:.2f}ms->{mae_after * 1e3:.2f}ms "
            f"({reduction:.1f}x) layers={int(recon.layer_covered.sum())}/"
            f"{recon.layer_covered.size} improved={improved}")
    return {"layers_covered": int(recon.layer_covered.sum()),
            "improved": improved,
            "mae_before_info": mae_before, "mae_after_info": mae_after,
            "layer_gap_info": float(recon.mean_layer_gap_s),
            "mae_reduction_info": reduction}


def _bench_transport(csv: Csv, quick: bool) -> dict:
    """E6: loopback exactness, bandwidth-calibrated re-solve, and the
    persistent-cache warm start (see module docstring; must run last)."""
    import tempfile

    from repro.exec import measure_warm_start
    from repro.transport import LoopbackTransport

    prob = _snapshot(8, 4, mem_mb=128, seed=0)
    fns = layer_fns_for(lenet_profile(), key=jax.random.PRNGKey(0))
    frames = np.random.default_rng(4).standard_normal(
        (4, *FRAME_HW)).astype(np.float32)
    planner = get_planner("ould-dp")
    plan = planner.plan(prob, SnapshotView(prob.rates))
    graph = compile_plan(plan)
    assert graph.transfers, "E6 scenario must ship bytes"

    ref = ExecutionEngine(fns).run(graph, frames)
    with LoopbackTransport(n_workers=2) as tp:
        engine = ExecutionEngine(fns, transport=tp)
        report = engine.run(
            graph, frames,
            predicted_s=np.asarray(plan.evaluate().per_request_s))
        exact = bool(all(np.array_equal(report.outputs[r], ref.outputs[r])
                         for r in graph.requests))
        n_workers = len(set(tp.worker_pids))
        cal_prob, recon = calibrated_problem(prob, report, transport=tp)
        replan = planner.plan(cal_prob, SnapshotView(cal_prob.rates))
        rereport = engine.run(
            compile_plan(replan), frames,
            predicted_s=np.asarray(replan.evaluate().per_request_s))
        _, recon2 = calibrated_problem(cal_prob, rereport, transport=tp)
        moved_mb = tp.moved_bytes / 1e6
        bw = float(np.mean([ls.bytes_per_s
                            for ls in tp.link_stats.values()]))
    comm_improved = bool(recon2.comm_mae_s < recon.comm_mae_s)

    # Fresh temp dir, NOT the CI-level cache: a pre-warmed dir would make
    # the cold pass a disk hit and deflate the strict speedup lock.
    with tempfile.TemporaryDirectory() as d:
        ws = measure_warm_start(fns, [(0, 3), (3, 7)], frames[0],
                                cache_dir=d)
    csv.add("exec/claims/E6_transport", ws.warm_total_s * 1e6,
            f"loopback workers={n_workers} exact={exact} "
            f"moved={moved_mb:.1f}MB bw={bw / 1e6:.0f}MB/s comm_mae "
            f"{recon.comm_mae_s * 1e3:.1f}ms->{recon2.comm_mae_s * 1e3:.1f}ms "
            f"improved={comm_improved} warm {ws.cold_total_s:.2f}s->"
            f"{ws.warm_total_s:.2f}s ({ws.speedup:.1f}x)")
    assert exact, "E6: loopback outputs diverged from in-proc"
    assert comm_improved, "E6: calibrated re-solve did not close the comm gap"
    assert ws.speedup > 1.0, f"E6: no warm-start benefit ({ws.summary()})"
    return {"loopback_exact": exact, "n_worker_processes": n_workers,
            "comm_source": cal_prob.comm_source,
            "comm_improved": comm_improved,
            "moved_mb_info": moved_mb, "mean_bandwidth_info": bw,
            "comm_mae_before_info": float(recon.comm_mae_s),
            "comm_mae_after_info": float(recon2.comm_mae_s),
            "warm_start_speedup": float(ws.speedup),
            "cold_compile_wall_info": ws.cold_total_s,
            "warm_compile_wall_info": ws.warm_total_s}


def run(csv: Csv, quick: bool = False) -> dict:
    from jax.sharding import Mesh

    # The engine's data mesh: every device the runtime offers (CI forces 8
    # host CPU devices); divisible batches shard across it, the rest run
    # on the default device.
    mesh = Mesh(np.array(jax.devices()), ("data",))
    engine = ExecutionEngine(
        layer_fns_for(lenet_profile(), key=jax.random.PRNGKey(0)), mesh=mesh)
    return {
        "equivalence": _bench_equivalence(csv, engine, quick),
        "dedup": _bench_dedup(csv, engine, quick),
        "pipeline": _bench_pipeline(csv, quick),
        "calibration": _bench_calibration(csv, engine, quick),
        "coalesce": _bench_coalesce(csv, engine, quick),
        # keep last: simulates a process restart (clears the jit cache)
        "transport": _bench_transport(csv, quick),
    }


if __name__ == "__main__":
    run(Csv(), quick=True)
