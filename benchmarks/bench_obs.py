"""Observability layer (repro.obs): tracing overhead + audited trace export.

Claims:
  T1  tracing is free when off and cheap when on: the default NullTracer
      path is bit-identical to the untraced simulator (hard assert on the
      S6 overload tape), and flight-recorder-on overhead stays < 10% of the
      untraced wall (soft bar — the ratio is committed as a report-only
      ``_info`` metric, a breach prints a warning instead of failing CI);
  T1a the exported Chrome trace is *audited*: per-frame span algebra
      ``frame.dur == base + queue_wait.dur + service.dur`` holds for every
      completion, and trace-event conservation matches SimResult exactly
      (served == outage instants + frame spans + drop instants + queue
      reject instants) — the per-frame reconstruction from the Lindley
      kernel outputs loses nothing.  (The tape runs in the pinned
      ``bottleneck`` compat mode; the per-hop twin of this audit — frame
      latency conserved across hop_wait/hop_service/link spans — is gated
      in ``bench_swarm`` S8 on the per-hop overload trace.)

Artifacts: ``trace_overload_{quick,full}.json`` (the audited S6 overload
trace, Perfetto-loadable — CI uploads the quick one, nightly the full ones)
and, in full mode, ``trace_s7_full.json`` — the batched-DP epoch solve
(S7) traced through the AdmissionController, whose solver spans carry the
``cold_dispatch`` / ``n_jit_compiles`` args that keep first-dispatch XLA
compile time from being misread as solve cost.
"""

from __future__ import annotations

import pathlib
import time

import numpy as np

from repro.obs import NullTracer, Tracer
from repro.runtime.swarm import simulate

from .bench_swarm import OVERLOAD
from .common import Csv

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts"

# Holds the whole overload trace (~3.4e5 events) without ring wraps, so the
# conservation audit counts every event.  Flight-recorder (smaller ring,
# newest events survive) is exercised by the unit tests, not here.
AUDIT_CAPACITY = 1 << 20

SOFT_OVERHEAD_BAR = 1.10


def _timed(fn, reps: int):
    best, res = float("inf"), None
    for _ in range(max(1, reps)):        # min-of-N: noise robust
        t0 = time.perf_counter()
        res = fn()
        best = min(best, time.perf_counter() - t0)
    return best, res


def _bench_overhead(csv: Csv, quick: bool) -> tuple[dict, Tracer]:
    """T1: traced-off vs NullTracer vs ring-buffer-on, one shared tape."""
    reps = 3 if quick else 5
    simulate(OVERLOAD, "nearest", seed=0)   # warm XLA before any timing
    off_s, r_off = _timed(lambda: simulate(OVERLOAD, "nearest", seed=0),
                          reps)
    null_s, r_null = _timed(
        lambda: simulate(OVERLOAD, "nearest", seed=0, tracer=NullTracer()),
        reps)
    # Construct each rep's tracer outside the timed window: ring allocation
    # is a once-per-process cost, not hot-path overhead (tracer.py pre-
    # faults the columns for the same reason).
    ring_s = float("inf")
    tracer: Tracer | None = None
    r_ring = None
    for _ in range(max(1, reps)):
        tr = Tracer(AUDIT_CAPACITY)
        t0 = time.perf_counter()
        r = simulate(OVERLOAD, "nearest", seed=0, tracer=tr)
        ring_s = min(ring_s, time.perf_counter() - t0)
        tracer, r_ring = tr, r

    identical = bool(
        r_off.served == r_null.served == r_ring.served
        and np.array_equal(r_off.latencies, r_null.latencies)
        and np.array_equal(r_off.latencies, r_ring.latencies)
        and (r_off.missed, r_off.outages, r_off.dropped,
             r_off.frames_rejected)
        == (r_ring.missed, r_ring.outages, r_ring.dropped,
            r_ring.frames_rejected))
    null_x = null_s / max(off_s, 1e-12)
    ring_x = ring_s / max(off_s, 1e-12)
    under_bar = ring_x < SOFT_OVERHEAD_BAR
    csv.add("obs/claims/T1_overhead", ring_s * 1e6,
            f"off={off_s * 1e6:.0f}us null_x={null_x:.3f} "
            f"ring_x={ring_x:.3f} events={tracer.n_events} "
            f"bit_identical={identical} under_10pct={under_bar}")
    assert identical, (
        "T1: tracing must never perturb the simulation "
        f"(served {r_off.served}/{r_null.served}/{r_ring.served})")
    if not under_bar:                    # soft bar: report, don't fail
        print(f"# WARNING obs/T1: ring-buffer tracing overhead "
              f"{ring_x:.3f}x exceeds the {SOFT_OVERHEAD_BAR:.2f}x soft bar")
    res = {"traced_off_s_info": off_s, "null_tracer_s_info": null_s,
           "ring_on_s_info": ring_s, "null_overhead_x_info": null_x,
           "ring_overhead_x_info": ring_x,
           "ring_under_10pct_info": bool(under_bar),
           "bit_identical": identical,
           "n_events": int(tracer.n_events),
           "n_ring_dropped": int(tracer.n_dropped)}
    return res, (tracer, r_ring)


def _audit_trace(csv: Csv, tracer: Tracer, r) -> dict:
    """T1a: span algebra + event conservation against SimResult."""
    f = tracer.select("frame")
    w = tracer.select("queue_wait")
    s = tracer.select("service")
    n_out = int(tracer.select("outage")["ts"].size)
    n_drop = int(tracer.select("drop")["ts"].size)
    n_rej = int(tracer.select("reject_queue")["ts"].size)

    aligned = bool(np.array_equal(f["frame"], w["frame"])
                   and np.array_equal(f["frame"], s["frame"]))
    algebra = aligned and bool(
        np.allclose(f["dur"], f["a0"] + w["dur"] + s["dur"]))
    conserved = bool(
        f["ts"].size == r.latencies.size
        and n_out == r.outages and n_drop == r.dropped
        and n_rej == r.frames_rejected
        and r.served == n_out + f["ts"].size + n_drop + n_rej)
    csv.add("obs/claims/T1a_trace_audit", 0.0,
            f"frames={f['ts'].size} outages={n_out} drops={n_drop} "
            f"rejects={n_rej} algebra={algebra} conserved={conserved}")
    assert algebra, "T1a: base + wait + service != frame latency"
    assert conserved, (
        f"T1a: trace events lost frames: served={r.served} vs "
        f"{n_out} + {f['ts'].size} + {n_drop} + {n_rej}")
    return {"frame_spans": int(f["ts"].size), "outage_events": n_out,
            "drop_events": n_drop, "reject_events": n_rej,
            "span_algebra_holds": algebra, "conservation_holds": conserved}


def _export_overload_trace(csv: Csv, tracer: Tracer, quick: bool) -> dict:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / ("trace_overload_quick.json" if quick
                        else "trace_overload_full.json")
    n = tracer.export_chrome(path)
    csv.add("obs/trace_export", 0.0,
            f"events={n} dropped={tracer.n_dropped} path={path.name}")
    return {"chrome_events": int(n), "path_info": str(path)}


def _export_s7_trace(csv: Csv) -> dict:
    """Nightly artifact: the S7 batched-DP epoch solve, traced through the
    controller — its solver spans carry cold_dispatch/n_jit_compiles, the
    fix that separates first-dispatch XLA compile time from solve cost."""
    from repro.core import SnapshotView
    from repro.runtime.serve import AdmissionController

    from .common import HIGH_MEM, snapshot_problem

    tracer = Tracer(1 << 16)
    prob = snapshot_problem("lenet", 256, 256, mem=8 * HIGH_MEM,
                            area=300.0, seed=0, hotspots=32)
    ctrl = AdmissionController("ould-dp-sparse", tracer=tracer,
                               batch_solve=True)
    view = SnapshotView(prob.rates)
    ctrl.admit(prob, view, request_ids=list(range(prob.n_requests)))
    ctrl.admit(prob, view, request_ids=list(range(prob.n_requests)))
    path = ARTIFACTS / "trace_s7_full.json"
    n = tracer.export_chrome(path)
    solves = tracer.select("solve")
    csv.add("obs/trace_export_s7", 0.0,
            f"events={n} solver_spans={solves['ts'].size} path={path.name}")
    return {"chrome_events": int(n),
            "solver_spans": int(solves["ts"].size),
            "path_info": str(path)}


def run(csv: Csv, quick: bool = False) -> dict:
    res: dict = {}
    res["t1_overhead"], (tracer, r_ring) = _bench_overhead(csv, quick)
    res["audit"] = _audit_trace(csv, tracer, r_ring)
    res["export"] = _export_overload_trace(csv, tracer, quick)
    if not quick:
        res["export_s7"] = _export_s7_trace(csv)
    return res


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    csv = Csv()
    print("name,us_per_call,derived")
    run(csv, quick=args.quick)


if __name__ == "__main__":
    main()
