"""Event-driven swarm serving: streaming requests on a moving, churning swarm.

The scenario matrix is pure iteration over the planner registry — pass any
set of registered strategy names:

    PYTHONPATH=src python -m benchmarks.bench_swarm \\
        --planners incremental,ould-mp,nearest

Claims:
  S1  on a churn scenario (two RPG groups converge/diverge past max_range,
      plus unpredicted node failures), OULD-MP's deadline-miss rate is lower
      than snapshot OULD's — the mobility-prediction argument of Fig. 13
      played forward as a serving stream;
  S2  warm-started incremental epoch re-solves reach the same objective as
      cold solves ≥ 2× faster (cached constraint structure + touched-request
      re-placement) on a slow-drift scenario;
  S3  every epoch's placement respects the capacity constraints (Eq. 4/5)
      for every policy — churn and mobility never break feasibility;
  S4  changed-row re-pricing of the transfer-cost matrix
      (``incremental_transfer_cost``) is bit-identical to full pricing and
      ≥ 2× faster when drift is localized (ROADMAP: N ≥ 50 swarms);
  S5  the sparse k-candidate DP (``ould-dp-sparse``) cold-solves N ≥ 50
      instances ≥ 3× faster than the dense DP at N = 128 — sub-quadratic
      transition scans + per-source stage memoization — while admitting
      exactly the same request set on these pinned seeds (the ladder
      guarantees per-request parity under equal residuals; whole-solve
      equality is the empirical acceptance bar this claim pins);
  S6  the queueing runtime under sustained overload (arrival work ≥ 2× the
      bottleneck node's capacity, ≥ 10⁵ frames even in quick mode): the
      drop and degrade service policies hold p99 latency strictly below the
      no-policy baseline on the identical event tape, queue-aware admission
      (expected wait = node backlog priced into the bar) cuts the deadline-
      miss rate vs path-cost-only admission, and the vectorized segmented-
      Lindley queue-advance kernel beats the per-frame python sweep by the
      margin that makes these scenario sizes feasible (the strict speedup
      lock).  Tail latencies are reported per policy as ungated ``_info``
      metrics; the claim booleans and counters are exact;
  S7  the batched jitted DP kernel (``batch_solve=True``) solves a whole
      epoch's request batch in one dispatch ≥ 5× faster than the sequential
      ``ould-dp-sparse`` request loop at N = 1024 — bit-identical admission,
      assignment, and objective — and the epoch re-solve fits the serving
      tick budget (the large-N frontier lock; ratio committed as a strict
      speedup lock in the baseline);
  S8  the per-hop tandem network (``queue_model="perhop"``, the serving
      default) prices the shared-uplink/relay contention the single
      bottleneck queue cannot see: on the identical overload tape the
      per-hop p99 sits strictly above the bottleneck-mode p99, the audited
      per-hop trace conserves every stream's latency across its
      hop_wait/hop_service/link spans, the hop-major tandem kernel beats
      the exact scalar python sweep (the strict speedup lock), and
      drift-triggered re-placement (``resolve_on_drift``) cuts the churn
      deadline-miss rate vs fixed-epoch re-solves on the same tape.
"""

from __future__ import annotations

import dataclasses
import pathlib
import time

import numpy as np

from repro.core import (SnapshotView, get_planner, incremental_transfer_cost,
                        transfer_cost)
from repro.obs import Tracer
from repro.runtime.queueing import (fifo_advance_kernel, n_path_resources,
                                    path_advance_kernel, path_sweep_reference)
from repro.runtime.swarm import (PLANNER_POLICIES, SwarmScenario,
                                 compare_policies, simulate, warm_vs_cold)

from .common import HIGH_MEM, Csv, snapshot_problem

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts"

# S1–S7 scenarios stay pinned to the bottleneck compat mode: their exact
# baseline metrics (miss rates, latencies, policy counters) were blessed
# under the single-queue model and the compat path is locked bit-identical
# to it, so the committed baseline keys never move when the per-hop default
# evolves.  S8 below is where the ``perhop`` default is exercised and gated.
# Non-homogeneous two-group sweep + node churn: inter-group links fade
# predictably (mobility), nodes drop unpredictably (failures).
CHURN = SwarmScenario(arrival_rate_hz=0.3, mtbf_s=60.0, mttr_s=20.0,
                      queue_model="bottleneck")

# Slow homogeneous drift, no memory pressure: the incremental solver keeps
# most placements — the regime S2's ≥2× re-solve speedup is measured in.
DRIFT = SwarmScenario(arrival_rate_hz=0.4, hold_ticks_mean=45.0,
                      mem_mb_hotspot_group=512.0, homogeneous=True,
                      epoch_ticks=2, rel_change=0.25, leader_speed_mps=1.0,
                      queue_model="bottleneck")

QUICK_PLANNERS = ("incremental", "incremental-sparse", "ould-mp", "nearest")

# S6: sustained overload.  ~1500 streams × ~100-tick average service windows
# ⇒ > 10⁵ frames per run; one RPG group (links stay strong, so tails are
# queue-driven, not fade-driven) and capacity uncapped at admission
# (memory/FLOPs generous) so pressure lands on the *queues*, not the
# placement solver — the regime where a saturated node chooses what to drop.
OVERLOAD = SwarmScenario(
    n_groups=1, duration_ticks=360, epoch_ticks=18, arrival_rate_hz=4.5,
    hold_ticks_mean=240.0, mem_mb_hotspot_group=4096.0,
    mem_mb_other_groups=4096.0, comp_cap_flops=1e18, gflops=5e9,
    deadline_s=2.0, mtbf_s=float("inf"), queue_model="bottleneck")


def _microbench_pricing(csv: Csv, quick: bool) -> dict:
    """S4: re-price only changed rows vs full horizon pricing."""
    # The regime the ROADMAP names (N ≥ 50, localized drift) — quick mode
    # trims repetitions, not the instance: smaller N can't amortize the
    # fixed costs (mask copy + gather) the entry win is measured against.
    n, t, moved = 128, 12, 5
    reps = 10 if quick else 40
    rng = np.random.default_rng(0)
    ref = rng.uniform(1e6, 1e8, (t, n, n))
    ref[:, np.arange(n), np.arange(n)] = np.inf
    new = ref.copy()
    idx = rng.choice(n, moved, replace=False)     # localized drift: c ≪ N
    new[:, idx, :] *= 1.3
    new[:, :, idx] *= 1.3
    new[:, np.arange(n), np.arange(n)] = np.inf
    ref_spb = transfer_cost(ref)

    # The hint a churn-aware caller has: exactly which nodes moved.
    hint = np.zeros((n, n), bool)
    hint[idx, :] = True
    hint[:, idx] = True

    full_t, inc_t, hint_t = [], [], []
    for _ in range(reps):                         # min-of-N: noise robust
        t0 = time.perf_counter()
        full = transfer_cost(new)
        full_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        spb, repriced = incremental_transfer_cost(new, ref, ref_spb)
        inc_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        spb_h, _ = incremental_transfer_cost(new, ref, ref_spb,
                                             repriced=hint)
        hint_t.append(time.perf_counter() - t0)
    full_s, inc_s, hint_s = min(full_t), min(inc_t), min(hint_t)

    exact = bool(np.array_equal(full, spb) and np.array_equal(full, spb_h))
    detect_x = full_s / max(inc_s, 1e-12)
    hint_x = full_s / max(hint_s, 1e-12)
    s4 = exact and detect_x >= 1.2 and hint_x >= 2.0
    csv.add("swarm/claims/S4_incremental_pricing", inc_s * 1e6,
            f"N={n} T={t} entries={int(repriced.sum())}/{n * n} "
            f"full={full_s * 1e6:.0f}us detected={detect_x:.1f}x "
            f"hinted={hint_x:.1f}x bit_identical={exact} holds={s4}")
    assert exact, "S4: incremental pricing must be bit-identical"
    return {"detected_speedup": detect_x, "hinted_speedup": hint_x,
            "bit_identical": exact, "entries_repriced": int(repriced.sum())}


def _bench_sparse_dp(csv: Csv, quick: bool) -> dict:
    """S5: sparse k-candidate DP vs dense DP, cold solves at N ≥ 50.

    Same instance generator at every size (hotspot sources, paper-calibrated
    caps, 300 m area so the swarm is spread but connected); quick mode trims
    the largest size and the repetitions, not the N = 128 claim instance.
    """
    sizes = (50, 128) if quick else (50, 128, 256)
    reps = 3 if quick else 5
    out: dict = {}
    for n in sizes:
        requests = max(16, n // 4)
        prob = snapshot_problem("lenet", n, requests, mem=HIGH_MEM,
                                area=300.0, seed=0, hotspots=5)
        view = SnapshotView(prob.rates)
        dense = get_planner("ould-dp")
        sparse = get_planner("ould-dp-sparse")
        dense_s, sparse_s = [], []
        pd = ps = None
        for _ in range(reps):                     # min-of-N: noise robust
            t0 = time.perf_counter()
            pd = dense.plan(prob, view)
            dense_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            ps = sparse.plan(prob, view)
            sparse_s.append(time.perf_counter() - t0)
        speedup = min(dense_s) / max(min(sparse_s), 1e-12)
        adm_equal = bool(np.array_equal(pd.admitted, ps.admitted))
        gap = ((ps.objective - pd.objective) / pd.objective
               if pd.objective > 0 else 0.0)
        st = ps.solve_stats
        csv.add(f"swarm/sparse_dp/N{n}", min(sparse_s) * 1e6,
                f"dense={min(dense_s) * 1e6:.0f}us speedup={speedup:.2f}x "
                f"k={st.k} pruned={st.pruned_fraction:.3f} "
                f"esc={st.n_escalations} dense_fb={st.n_dense_fallback} "
                f"adm={ps.n_admitted}/{requests} adm_equal={adm_equal} "
                f"obj_gap={gap:+.4f}")
        # Acceptance bar on THIS pinned instance, not a structural invariant:
        # at k < N admitted paths may differ, residuals diverge, and a later
        # admission can legitimately flip on other instances.
        assert adm_equal, (
            f"S5: sparse DP admission diverged from dense at N={n}")
        out[f"N{n}"] = {"requests": requests,
                        "dense_solve_s": min(dense_s),
                        "sparse_solve_s": min(sparse_s),
                        "speedup": speedup, "k": st.k,
                        "pruned_fraction": st.pruned_fraction,
                        "admitted": ps.n_admitted,
                        "admission_equal": adm_equal,
                        "objective_gap": gap}
    s5 = out["N128"]["speedup"] >= (2.0 if quick else 3.0)
    csv.add("swarm/claims/S5_sparse_dp", out["N128"]["sparse_solve_s"] * 1e6,
            f"speedup_N128={out['N128']['speedup']:.2f}x "
            f"adm_equal={out['N128']['admission_equal']} holds={s5}")
    # quick mode keeps a noise-tolerant floor (shared CI runners); the full
    # run pins the ≥ 3× claim the ROADMAP records.
    assert s5, (f"S5: sparse DP speedup {out['N128']['speedup']:.2f}x "
                f"at N=128 below the bar")
    return out


def _bench_batched_dp(csv: Csv, quick: bool) -> dict:
    """S7: batched jitted DP epoch solve vs the sequential request loop.

    Same planner (``ould-dp-sparse``), same instance — ``batch_solve=True``
    stacks every request's pruned candidate set and runs all (M-1, k, k)
    min-plus sweeps in one jitted dispatch (``core/batch_dp``), with the
    fallback ladder applied sequentially only to requests the batched pass
    rejects.  Regime: a provisioned swarm (8× the paper's HIGH_MEM) with
    hotspot sources, the epoch re-solve shape the large-N frontier needs —
    residual-capacity feasibility bits rarely flip mid-batch, so the
    certified fast path stays hot.  Quick mode keeps BOTH sizes: N = 1024
    is *the* claim instance and its speedup ratio is the strict lock.
    """
    reps = 3 if quick else 5
    tick_s = SwarmScenario().tick_s
    out: dict = {}
    for n, hot in ((256, 32), (1024, 64)):
        prob = snapshot_problem("lenet", n, n, mem=8 * HIGH_MEM,
                                area=300.0, seed=0, hotspots=hot)
        view = SnapshotView(prob.rates)
        seq = get_planner("ould-dp-sparse")
        bat = get_planner("ould-dp-sparse", batch_solve=True)
        bat.plan(prob, view)                 # jit compile off the clock
        seq_s, bat_s = [], []
        ps = pb = None
        for _ in range(reps):                # min-of-N: noise robust
            t0 = time.perf_counter()
            ps = seq.plan(prob, view)
            seq_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            pb = bat.plan(prob, view)
            bat_s.append(time.perf_counter() - t0)
        speedup = min(seq_s) / max(min(bat_s), 1e-12)
        identical = bool(np.array_equal(ps.admitted, pb.admitted)
                         and np.array_equal(ps.assign, pb.assign)
                         and ps.objective == pb.objective)
        st = pb.solve_stats
        under_tick = bool(min(bat_s) <= tick_s)
        csv.add(f"swarm/batched_dp/N{n}", min(bat_s) * 1e6,
                f"sequential={min(seq_s) * 1e6:.0f}us "
                f"speedup={speedup:.2f}x batched={st.n_batched}/{n} "
                f"adm={pb.n_admitted}/{n} identical={identical} "
                f"under_tick={under_tick}")
        # Bit-identity is the contract, not an acceptance bar: the batched
        # kernel must reproduce the sequential solve exactly, always.
        assert identical, (
            f"S7: batched DP diverged from sequential at N={n}")
        out[f"N{n}"] = {"requests": n,
                        "sequential_solve_s": min(seq_s),
                        "batched_solve_s": min(bat_s),
                        "batch_speedup": speedup,
                        "n_batched": st.n_batched,
                        "n_ladder_fallback": n - st.n_batched,
                        "admitted": pb.n_admitted,
                        "bit_identical": identical,
                        "under_tick_budget": under_tick}
    s7 = (out["N1024"]["batch_speedup"] >= (4.0 if quick else 5.0)
          and out["N1024"]["under_tick_budget"])
    csv.add("swarm/claims/S7_batched_dp",
            out["N1024"]["batched_solve_s"] * 1e6,
            f"speedup_N1024={out['N1024']['batch_speedup']:.2f}x "
            f"speedup_N256={out['N256']['batch_speedup']:.2f}x "
            f"identical={out['N1024']['bit_identical']} holds={s7}")
    # quick keeps a noise-tolerant floor (shared CI runners); the full run
    # pins the ≥ 5× acceptance bar, and the committed baseline speedup
    # ratio is the strict cross-machine lock either way.
    assert s7, (f"S7: batched DP speedup "
                f"{out['N1024']['batch_speedup']:.2f}x at N=1024 below the "
                f"bar (under_tick={out['N1024']['under_tick_budget']})")
    return out


def _bench_queue_kernel(csv: Csv, quick: bool) -> dict:
    """The S6 lock: vectorized segmented-Lindley queue advance vs the exact
    per-frame python sweep, same inputs, identical outputs."""
    n, nodes = (200_000 if quick else 1_000_000), 10
    reps = 3
    rng = np.random.default_rng(0)
    node = np.sort(rng.integers(0, nodes, n))
    arrival = np.empty(n)
    for k in range(nodes):                     # per-node time-ordered frames
        m = node == k
        arrival[m] = np.sort(rng.uniform(0.0, 300.0, int(m.sum())))
    service = rng.uniform(0.01, 0.05, n)
    free = rng.uniform(0.0, 1.0, nodes)

    def python_sweep():
        start = np.empty(n)
        finish = np.empty(n)
        busy = free.copy()
        nl, al, sl = node.tolist(), arrival.tolist(), service.tolist()
        for i in range(n):
            s = max(al[i], busy[nl[i]])
            start[i], finish[i] = s, s + sl[i]
            busy[nl[i]] = s + sl[i]
        return start, finish

    vec_s, ref_s = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        vs, vf = fifo_advance_kernel(node, arrival, service, free)
        vec_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rs, rf = python_sweep()
        ref_s.append(time.perf_counter() - t0)
    # pairwise (cumsum) vs sequential summation: same math, different fp
    # association — equal to ~1e-9 s at these segment lengths
    exact = bool(np.allclose(vs, rs, rtol=0.0, atol=1e-6)
                 and np.allclose(vf, rf, rtol=0.0, atol=1e-6))
    speedup = min(ref_s) / max(min(vec_s), 1e-12)
    csv.add("swarm/claims/S6_queue_kernel", min(vec_s) * 1e6,
            f"frames={n} sweep={min(ref_s) * 1e6:.0f}us "
            f"speedup={speedup:.1f}x exact={exact}")
    assert exact, "S6: vectorized queue kernel diverged from python sweep"
    assert speedup > 1.0, f"S6: queue kernel speedup {speedup:.2f}x"
    return {"frames": n, "exact": exact, "kernel_wall_info": min(vec_s),
            "sweep_wall_info": min(ref_s), "queue_kernel_speedup": speedup}


def _bench_overload(csv: Csv, quick: bool) -> dict:
    """S6: service policies + queue-aware admission under sustained overload
    (one shared event tape; 'nearest' keeps the placement layer cheap and
    deterministic so the queueing layer is what's measured)."""
    res: dict = {}
    runs = {
        "none": simulate(OVERLOAD, "nearest", seed=0),
        "drop": simulate(
            dataclasses.replace(OVERLOAD, service_policy="fifo+drop"),
            "nearest", seed=0),
        "degrade": simulate(
            dataclasses.replace(OVERLOAD,
                                service_policy="fifo+degrade:0.25"),
            "nearest", seed=0),
        "edf+drop": simulate(
            dataclasses.replace(OVERLOAD, service_policy="edf+drop"),
            "nearest", seed=0),
    }
    none = runs["none"]
    # Realized overload factor: offered service seconds at the hottest
    # queue vs what one node can drain over the horizon (1 s per second).
    horizon_s = OVERLOAD.duration_ticks * OVERLOAD.tick_s
    overload_x = float(none.queue_demand_s.max() / horizon_s)
    res["overload_factor"] = float(round(overload_x, 3))
    res["policies"] = {}
    for name, r in runs.items():
        res["policies"][name] = {
            "served": r.served, "missed": r.missed, "outages": r.outages,
            "dropped": r.dropped, "degraded": r.degraded,
            "frames_rejected": r.frames_rejected,
            "completions": int(r.latencies.size),
            "miss": r.deadline_miss_rate,
            "p50_s_info": r.p50_latency_s,
            "p99_s_info": r.p99_latency_s,
            "p999_s_info": r.p999_latency_s,
        }
        csv.add(f"swarm/overload/{name}", r.p99_latency_s * 1e6,
                f"served={r.served} miss={r.deadline_miss_rate:.3f} "
                f"p50={r.p50_latency_s:.2f}s p99={r.p99_latency_s:.2f}s "
                f"p999={r.p999_latency_s:.2f}s dropped={r.dropped} "
                f"degraded={r.degraded}")
    n_frames = none.served
    tails_hold = (runs["drop"].p99_latency_s < none.p99_latency_s
                  and runs["degrade"].p99_latency_s < none.p99_latency_s)
    res["n_frames"] = n_frames
    res["tail_policy_holds"] = bool(tails_hold)
    assert n_frames >= 100_000, f"S6 underloaded: only {n_frames} frames"
    assert overload_x >= 2.0, (
        f"S6 scenario not overloaded enough: ρ ≈ {overload_x:.2f}")
    assert tails_hold, (
        "S6: drop/degrade must beat the no-policy p99 under overload: "
        f"none={none.p99_latency_s:.2f}s drop={runs['drop'].p99_latency_s:.2f}s "
        f"degrade={runs['degrade'].p99_latency_s:.2f}s")

    # queue-aware admission vs path-cost-only on the same tape
    aware = simulate(dataclasses.replace(OVERLOAD,
                                         queue_aware_admission=True),
                     "nearest", seed=0)
    n_gated = sum(e.n_queue_rejected for e in aware.epochs)
    aware_wins = aware.deadline_miss_rate < none.deadline_miss_rate
    res["admission"] = {
        "blind_miss": none.deadline_miss_rate,
        "aware_miss": aware.deadline_miss_rate,
        "queue_rejected": n_gated, "aware_wins": bool(aware_wins),
    }
    csv.add("swarm/claims/S6_overload", 0.0,
            f"frames={n_frames} rho={overload_x:.2f} "
            f"blind_miss={none.deadline_miss_rate:.3f} "
            f"aware_miss={aware.deadline_miss_rate:.3f} gated={n_gated} "
            f"tails_hold={tails_hold} aware_wins={aware_wins}")
    assert aware.n_arrivals == none.n_arrivals     # same tape
    assert n_gated > 0, "S6: queue-aware admission never engaged"
    assert aware_wins, (
        f"S6: queue-aware admission miss {aware.deadline_miss_rate:.3f} not "
        f"below path-cost-only {none.deadline_miss_rate:.3f}")
    return res


def _bench_path_kernel(csv: Csv, quick: bool) -> dict:
    """The S8 lock: hop-major tandem advance (compute + link servers in one
    resource space) vs the exact scalar python sweep, same inputs."""
    frames, hops, nodes = (20_000 if quick else 100_000), 6, 12
    reps = 3
    rng = np.random.default_rng(0)
    n_res = n_path_resources(nodes)
    res = rng.integers(0, n_res, (frames, hops))
    res[rng.random((frames, hops)) < 0.25] = -1    # padded short paths
    service = rng.uniform(0.005, 0.05, (frames, hops))
    arrival = np.sort(rng.uniform(0.0, 300.0, frames))
    free = rng.uniform(0.0, 0.5, n_res)

    vec_s, ref_s = [], []
    for _ in range(reps):                          # min-of-N: noise robust
        t0 = time.perf_counter()
        vs, vf, _ = path_advance_kernel(res, service, arrival, free)
        vec_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        ss, sf, _ = path_sweep_reference(res, service, arrival, free)
        ref_s.append(time.perf_counter() - t0)
    # segmented cumsum vs sequential max/add: same math, different fp
    # association — equal to well under 1e-6 s at these segment lengths
    exact = bool(np.allclose(vs, ss, rtol=0.0, atol=1e-6)
                 and np.allclose(vf, sf, rtol=0.0, atol=1e-6))
    speedup = min(ref_s) / max(min(vec_s), 1e-12)
    csv.add("swarm/claims/S8_path_kernel", min(vec_s) * 1e6,
            f"frames={frames} hops={hops} resources={n_res} "
            f"sweep={min(ref_s) * 1e6:.0f}us speedup={speedup:.1f}x "
            f"exact={exact}")
    assert exact, "S8: tandem path kernel diverged from the python sweep"
    assert speedup > 1.0, f"S8: path kernel speedup {speedup:.2f}x"
    return {"frames": frames, "hops": hops, "exact": exact,
            "kernel_wall_info": min(vec_s), "sweep_wall_info": min(ref_s),
            "path_kernel_speedup": speedup}


def _perstream_sums(ids: np.ndarray, durs: np.ndarray):
    """Total span seconds per stream id (frame ids are stream ids — one
    frame per tick per stream — so conservation is a per-stream aggregate)."""
    u, inv = np.unique(ids, return_inverse=True)
    return u, np.bincount(inv, weights=durs)


def _bench_perhop_contention(csv: Csv, quick: bool) -> dict:
    """S8: per-hop tandem vs the bottleneck compat mode on the identical
    overload tape — the shared source-uplink serialization the single
    bottleneck queue prices at zero — plus the audited per-hop trace
    artifact (hop spans conserve every stream's queued latency)."""
    tracer = Tracer(1 << 20)     # holds the whole per-hop trace, no wraps
    bott = simulate(OVERLOAD, "nearest", seed=0)
    per = simulate(dataclasses.replace(OVERLOAD, queue_model="perhop"),
                   "nearest", seed=0, tracer=tracer)
    assert per.n_arrivals == bott.n_arrivals       # same event tape
    sees = bool(per.p99_latency_s > bott.p99_latency_s)
    gap = per.p99_latency_s - bott.p99_latency_s

    # per-hop conservation audit: frame spans vs hop spans, per stream
    f = tracer.select("frame")
    hop_ids = np.concatenate([tracer.select(nm)["frame"]
                              for nm in ("hop_wait", "hop_service", "link")])
    hop_durs = np.concatenate([tracer.select(nm)["dur"]
                               for nm in ("hop_wait", "hop_service", "link")])
    fu, fsum = _perstream_sums(f["frame"], f["dur"])
    hu, hsum = _perstream_sums(hop_ids, hop_durs)
    conserved = bool(tracer.n_dropped == 0
                     and f["ts"].size == per.latencies.size
                     and np.array_equal(fu, hu)
                     and np.allclose(fsum, hsum, rtol=0.0, atol=1e-6))

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / ("trace_s8_perhop_quick.json" if quick
                        else "trace_s8_perhop_full.json")
    n_events = tracer.export_chrome(path)
    csv.add("swarm/claims/S8_perhop_contention", 0.0,
            f"frames={per.latencies.size} "
            f"bottleneck_p99={bott.p99_latency_s:.2f}s "
            f"perhop_p99={per.p99_latency_s:.2f}s gap={gap:.2f}s "
            f"hop_spans={hop_ids.size} conserved={conserved} "
            f"events={n_events} path={path.name} holds={sees}")
    assert sees, (
        "S8: per-hop p99 must sit strictly above the bottleneck p99 on the "
        f"contended tape: perhop={per.p99_latency_s:.2f}s "
        f"bottleneck={bott.p99_latency_s:.2f}s")
    assert conserved, (
        f"S8: per-hop spans lost latency: {f['ts'].size} frame spans / "
        f"{hop_ids.size} hop spans / dropped={tracer.n_dropped}")
    return {"n_frames": int(per.latencies.size),
            "hop_spans": int(hop_ids.size),
            "chrome_events": int(n_events),
            "perhop_sees_contention": sees,
            "trace_conserved": conserved,
            "bottleneck_p99_s_info": bott.p99_latency_s,
            "perhop_p99_s_info": per.p99_latency_s,
            "p99_gap_s_info": gap}


def _bench_drift_resolve(csv: Csv, quick: bool) -> dict:
    """S8 rider: drift-triggered re-placement (``resolve_on_drift``) vs
    fixed-epoch re-solves alone on the churn tape — same arrivals, same
    failures, the extra mid-epoch re-solves fire only when realized
    placement drift crosses the threshold."""
    base = dataclasses.replace(CHURN, queue_model="perhop", epoch_ticks=45)
    fixed = simulate(base, "incremental", seed=0)
    drift = simulate(dataclasses.replace(base, resolve_on_drift=0.05),
                     "incremental", seed=0)
    assert drift.n_arrivals == fixed.n_arrivals    # same event tape
    wins = bool(drift.loss_rate < fixed.loss_rate)
    csv.add("swarm/claims/S8_drift_resolve", 0.0,
            f"fixed_miss={fixed.loss_rate:.3f} "
            f"drift_miss={drift.loss_rate:.3f} "
            f"drift_resolves={drift.drift_resolves} holds={wins}")
    assert drift.drift_resolves > 0, "S8: drift trigger never fired"
    assert wins, (
        f"S8: drift-triggered re-placement miss {drift.loss_rate:.3f} not "
        f"below fixed-epoch {fixed.loss_rate:.3f}")
    return {"fixed_miss": fixed.loss_rate, "drift_miss": drift.loss_rate,
            "drift_resolves": int(drift.drift_resolves),
            "drift_wins": wins}


def run(csv: Csv, quick: bool = False, planners=None) -> dict:
    res: dict = {}
    # --- S1/S3: policy comparison on the churn scenario --------------------
    # quick mode trims the policy set, not the horizon: the MP advantage
    # needs the full converge→diverge sweep of the two groups.
    planners = tuple(planners) if planners else (
        QUICK_PLANNERS if quick else PLANNER_POLICIES)
    results = compare_policies(CHURN, seed=0, policies=planners)
    for pol, r in results.items():
        csv.add(f"swarm/churn/{pol}", r.total_resolve_s * 1e6,
                f"miss={r.deadline_miss_rate:.3f} "
                f"(deadline={r.over_deadline_miss_rate:.3f} "
                f"outage={r.outage_rate:.3f}) rej={r.rejection_rate:.3f} "
                f"lat={r.avg_latency_s:.3f}s served={r.served} "
                f"warm_starts={r.warm_starts}")
        res[pol] = {"miss": r.deadline_miss_rate, "rej": r.rejection_rate,
                    "lat": r.avg_latency_s, "outages": r.outages,
                    "over_deadline_miss": r.over_deadline_miss_rate,
                    "warm_starts": r.warm_starts}
        # the decomposition is exact: every miss is late or an outage
        assert r.missed >= r.outages
        assert all(e.feasible for e in r.epochs), f"S3 violated: {pol}"
    if {"incremental", "ould-mp"} <= set(results):
        s1 = (results["ould-mp"].deadline_miss_rate
              < results["incremental"].deadline_miss_rate)
        csv.add("swarm/claims/S1_mp_beats_snapshot", 0.0,
                f"mp_miss={results['ould-mp'].deadline_miss_rate:.3f} "
                f"ould_miss={results['incremental'].deadline_miss_rate:.3f} "
                f"holds={s1}")
        assert s1, "S1: OULD-MP should out-serve snapshot OULD under churn"

    # --- S2: warm vs cold epoch re-solves ----------------------------------
    trials = 2 if quick else 5
    warm_s, cold_s, obj = [], [], []
    for _ in range(trials):           # min-of-N: wall-clock robust to noise
        wc = warm_vs_cold(DRIFT, seed=0)
        warm_s.append(wc["warm_solve_s"])
        cold_s.append(wc["cold_solve_s"])
        obj.append(wc["objective_ratio_max"])
    speedup = min(cold_s) / min(warm_s)
    kept = sum(e.n_kept for e in wc["warm"].epochs)
    rep = sum(e.n_replaced for e in wc["warm"].epochs)
    s2 = speedup >= 2.0 and max(obj) <= 1.01
    csv.add("swarm/claims/S2_warm_resolve", min(warm_s) * 1e6,
            f"speedup={speedup:.2f}x obj_ratio={max(obj):.4f} "
            f"kept={kept} replaced={rep} holds={s2}")
    res["warm_vs_cold"] = {"speedup": speedup, "objective_ratio": max(obj),
                           "kept": kept, "replaced": rep}
    if not quick:
        assert s2, (f"S2: warm re-solve speedup {speedup:.2f}x "
                    f"(obj ratio {max(obj):.4f})")

    # --- S4: incremental transfer-cost pricing -----------------------------
    res["incremental_pricing"] = _microbench_pricing(csv, quick)

    # --- S5: sparse k-candidate DP at N ≥ 50 -------------------------------
    res["sparse_dp"] = _bench_sparse_dp(csv, quick)

    # --- S6: queueing runtime under overload -------------------------------
    res["queue_kernel"] = _bench_queue_kernel(csv, quick)
    res["overload"] = _bench_overload(csv, quick)

    # --- S7: batched jitted DP epoch solve ---------------------------------
    res["batched_dp"] = _bench_batched_dp(csv, quick)

    # --- S8: per-hop tandem path queueing ----------------------------------
    res["path_kernel"] = _bench_path_kernel(csv, quick)
    res["perhop"] = _bench_perhop_contention(csv, quick)
    res["drift_resolve"] = _bench_drift_resolve(csv, quick)
    return res


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--planners", default=None,
                    help="comma-separated registry names "
                         "(default: " + ",".join(PLANNER_POLICIES) + ")")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    names = args.planners.split(",") if args.planners else None
    csv = Csv()
    print("name,us_per_call,derived")
    run(csv, quick=args.quick, planners=names)


if __name__ == "__main__":
    main()
