"""Event-driven swarm serving: streaming requests on a moving, churning swarm.

The first workload where OULD-MP's horizon objective measurably pays off.

Claims:
  S1  on a churn scenario (two RPG groups converge/diverge past max_range,
      plus unpredicted node failures), OULD-MP's deadline-miss rate is lower
      than snapshot OULD's — the mobility-prediction argument of Fig. 13
      played forward as a serving stream;
  S2  warm-started incremental epoch re-solves reach the same objective as
      cold solves ≥ 2× faster (cached constraint structure + touched-request
      re-placement) on a slow-drift scenario;
  S3  every epoch's placement respects the capacity constraints (Eq. 4/5)
      for every policy — churn and mobility never break feasibility.
"""

from __future__ import annotations

import numpy as np

from repro.runtime.swarm import (SwarmScenario, compare_policies, simulate,
                                 warm_vs_cold)

from .common import Csv

# Non-homogeneous two-group sweep + node churn: inter-group links fade
# predictably (mobility), nodes drop unpredictably (failures).
CHURN = SwarmScenario(arrival_rate_hz=0.3, mtbf_s=60.0, mttr_s=20.0)

# Slow homogeneous drift, no memory pressure: the incremental solver keeps
# most placements — the regime S2's ≥2× re-solve speedup is measured in.
DRIFT = SwarmScenario(arrival_rate_hz=0.4, hold_ticks_mean=45.0,
                      mem_mb_hotspot_group=512.0, homogeneous=True,
                      epoch_ticks=2, rel_change=0.25, leader_speed_mps=1.0)

def run(csv: Csv, quick: bool = False) -> dict:
    res: dict = {}

    # --- S1/S3: policy comparison on the churn scenario --------------------
    # quick mode trims the policy set, not the horizon: the MP advantage
    # needs the full converge→diverge sweep of the two groups.
    policies = (("ould", "ould_mp", "nearest") if quick else
                ("ould", "ould_mp", "nearest", "hrm", "nearest_hrm"))
    results = compare_policies(CHURN, seed=0, policies=policies)
    for pol, r in results.items():
        csv.add(f"swarm/churn/{pol}", r.total_resolve_s * 1e6,
                f"miss={r.deadline_miss_rate:.3f} rej={r.rejection_rate:.3f} "
                f"lat={r.avg_latency_s:.3f}s served={r.served}")
        res[pol] = {"miss": r.deadline_miss_rate, "rej": r.rejection_rate,
                    "lat": r.avg_latency_s}
        assert all(e.feasible for e in r.epochs), f"S3 violated: {pol}"
    s1 = (results["ould_mp"].deadline_miss_rate
          < results["ould"].deadline_miss_rate)
    csv.add("swarm/claims/S1_mp_beats_snapshot", 0.0,
            f"mp_miss={results['ould_mp'].deadline_miss_rate:.3f} "
            f"ould_miss={results['ould'].deadline_miss_rate:.3f} holds={s1}")
    assert s1, "S1: OULD-MP should out-serve snapshot OULD under churn"

    # --- S2: warm vs cold epoch re-solves ----------------------------------
    trials = 2 if quick else 5
    warm_s, cold_s, obj = [], [], []
    for _ in range(trials):           # min-of-N: wall-clock robust to noise
        wc = warm_vs_cold(DRIFT, seed=0)
        warm_s.append(wc["warm_solve_s"])
        cold_s.append(wc["cold_solve_s"])
        obj.append(wc["objective_ratio_max"])
    speedup = min(cold_s) / min(warm_s)
    kept = sum(e.n_kept for e in wc["warm"].epochs)
    rep = sum(e.n_replaced for e in wc["warm"].epochs)
    s2 = speedup >= 2.0 and max(obj) <= 1.01
    csv.add("swarm/claims/S2_warm_resolve", min(warm_s) * 1e6,
            f"speedup={speedup:.2f}x obj_ratio={max(obj):.4f} "
            f"kept={kept} replaced={rep} holds={s2}")
    res["warm_vs_cold"] = {"speedup": speedup, "objective_ratio": max(obj),
                           "kept": kept, "replaced": rep}
    if not quick:
        assert s2, (f"S2: warm re-solve speedup {speedup:.2f}x "
                    f"(obj ratio {max(obj):.4f})")
    return res
