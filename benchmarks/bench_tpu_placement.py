"""Beyond-paper: OULD as the pipeline-placement engine on a TPU topology.

Places each assigned architecture's blocks over 16 chip-groups connected by
the ICI hop-rate model and compares the OULD cut against a FLOPs-balanced
contiguous split ([32]-style static baseline) on the same latency model.

Claim: OULD's communication objective never loses to the balanced split,
and wins when layer activation sizes are heterogeneous."""

from __future__ import annotations

import numpy as np

import repro.configs as C
from repro.core import (Problem, SnapshotView, Solution, evaluate,
                        get_planner, lm_profile)
from repro.core.placement import balanced_stages
from repro.core.radio import TpuLinkModel

from .common import Csv, timed

HBM = 16e9              # v5e per chip
PEAK = 197e12


def _profile(arch: str, seq: int = 4096, batch: int = 8):
    cfg = C.get_config(arch)
    return lm_profile(
        cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_ff=cfg.d_ff, vocab=cfg.vocab,
        seq=seq, batch=batch,
        moe_experts=cfg.moe.num_experts if cfg.moe else 0,
        moe_topk=cfg.moe.top_k if cfg.moe else 0, window=cfg.window)


def run(csv: Csv) -> dict:
    link = TpuLinkModel()
    n_groups = 16
    res = {}
    wins = ties = rejected = 0
    planner = get_planner("ould-dp")
    for arch in C.ARCH_IDS:
        prof = _profile(arch)
        coords = np.stack([np.arange(n_groups) % 16,
                           np.arange(n_groups) // 16], -1)
        rho = link.rate_matrix(coords, np.zeros(n_groups, np.int64))
        prob = Problem(prof, np.full(n_groups, HBM * 16),
                       np.full(n_groups, PEAK * 10),
                       rho * 8.0, np.zeros(1, np.int64),
                       compute_speed=np.full(n_groups, PEAK))
        plan, us = timed(planner.plan, prob, SnapshotView(prob.rates))
        if not plan.admitted[0]:
            # Pre-existing greedy-DP conservatism (repair loop may fail to
            # spread a huge single request); report honestly instead of the
            # seed's silent comm=0 "win".
            csv.add(f"tpu_placement/{arch}", us,
                    f"REJECTED by {plan.planner_name}: status={plan.status}")
            res[arch] = None
            rejected += 1
            continue
        ev = plan.evaluate()
        # balanced baseline evaluated on the same objective
        bal = balanced_stages(prof, n_groups)
        assign = np.zeros((1, prof.num_layers), np.int64)
        for st in bal:
            assign[0, st.layer_start:st.layer_end] = st.node
        ev_bal = evaluate(prob, Solution(assign, 0.0, "feasible", 0.0,
                                         np.ones(1, bool)))
        stages = plan.stages(0)
        better = ev.comm_latency_s <= ev_bal.comm_latency_s + 1e-12
        wins += better and ev.comm_latency_s < ev_bal.comm_latency_s - 1e-12
        ties += abs(ev.comm_latency_s - ev_bal.comm_latency_s) <= 1e-12
        res[arch] = (ev.comm_latency_s, ev_bal.comm_latency_s, len(stages))
        csv.add(f"tpu_placement/{arch}", us,
                f"ould_comm={ev.comm_latency_s * 1e6:.1f}us "
                f"balanced={ev_bal.comm_latency_s * 1e6:.1f}us "
                f"stages={len(stages)} ould<=balanced={better}")
    compared = len(C.ARCH_IDS) - rejected
    csv.add("tpu_placement/claims", 0.0,
            f"ould_never_worse={wins + ties == compared} wins={wins} "
            f"rejected={rejected}")
    return res
