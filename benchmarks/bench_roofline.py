"""§Roofline: reads the dry-run artifacts and emits the three-term roofline
table per (arch × shape) on the single-pod mesh (+ multi-pod pass/fail).

Terms (seconds, per spec):
  compute    = HLO_FLOPs  / (chips × 197 TFLOP/s)
  memory     = HLO_bytes  / (chips × 819 GB/s)
  collective = collective_bytes / (chips × 50 GB/s per link)

HLO_FLOPs/bytes are the probe-derived per-partition values × chips (the
two-point probe corrects XLA's count-loop-body-once behaviour; see
launch/dryrun.py).  MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE),
×(1/3) for inference shapes (forward only ⇒ 2·N·D).
"""

from __future__ import annotations

import json
import pathlib

import repro.configs as C
from repro.configs.base import SHAPES

from .common import Csv

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def active_params(arch: str) -> float:
    cfg = C.get_config(arch)
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.hd
    attn = d * (cfg.n_heads + 2 * cfg.n_kv) * hd + cfg.n_heads * hd * d
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim
                                                  + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * d)
    if cfg.moe is not None:
        ffn = cfg.moe.top_k * 3 * d * cfg.d_ff + d * cfg.moe.num_experts
    elif cfg.d_ff > 0:
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = 0
    per_block = attn + ffn
    if "mlstm" in cfg.block_pattern:
        di = 2 * d
        per_block = (d * 2 * di + di * 3 * di + di * 2 * cfg.n_heads
                     + di * d) * 7 / 8 + (4 * d * d + d * d) / 8
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.expand * d
        per_block = attn + 3 * d * cfg.d_ff + 2 * d * di + di * d
    embed = 2 * cfg.vocab * d
    return L * per_block + embed


def model_flops(arch: str, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    n = active_params(arch)
    if sh.kind == "train":
        tokens = sh.seq_len * sh.global_batch
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        return 2.0 * n * sh.seq_len * sh.global_batch
    return 2.0 * n * sh.global_batch        # decode: one token per sequence


def load(arch: str, shape: str, mesh: str) -> dict | None:
    p = ART / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_row(rec: dict) -> dict:
    chips = rec["chips"]
    flops_pp = rec.get("derived_flops_per_partition",
                       rec.get("flops_per_partition", 0.0))
    bytes_pp = rec.get("derived_bytes_per_partition",
                       rec.get("bytes_per_partition", 0.0))
    coll_pp = rec.get("derived_coll_per_partition",
                      rec["collectives"]["weighted_link_traffic"])
    t_c = flops_pp / PEAK_FLOPS
    t_m = bytes_pp / HBM_BW
    t_l = coll_pp / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"])
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
            "dominant": dom, "model_flops": mf,
            "useful_ratio": mf / max(flops_pp * chips, 1.0),
            "roofline_frac": max(t_c, t_m, t_l) and t_c / max(t_c, t_m, t_l)}


def run(csv: Csv) -> dict:
    res = {}
    for arch in C.ARCH_IDS:
        for shape in SHAPES:
            rec = load(arch, shape, "single")
            multi = load(arch, shape, "multi")
            mstat = multi["status"] if multi else "missing"
            if rec is None:
                csv.add(f"roofline/{arch}/{shape}", 0.0, "missing")
                continue
            if rec["status"] == "skipped":
                csv.add(f"roofline/{arch}/{shape}", 0.0,
                        f"SKIP ({rec['reason']}) multi={mstat}")
                continue
            if rec["status"] != "ok":
                csv.add(f"roofline/{arch}/{shape}", 0.0,
                        f"ERROR {rec.get('error', '?')[:80]}")
                continue
            row = roofline_row(rec)
            res[f"{arch}/{shape}"] = row
            csv.add(f"roofline/{arch}/{shape}", rec["compile_s"] * 1e6,
                    f"compute={row['compute_s']:.4f}s "
                    f"memory={row['memory_s']:.4f}s "
                    f"collective={row['collective_s']:.4f}s "
                    f"dom={row['dominant']} "
                    f"useful={row['useful_ratio']:.2f} multi={mstat}")
            opt = load(arch, shape, "single__opt")
            if opt and opt["status"] == "ok":
                o = roofline_row(opt)
                base_dom = max(row["compute_s"], row["memory_s"],
                               row["collective_s"])
                opt_dom = max(o["compute_s"], o["memory_s"],
                              o["collective_s"])
                res[f"{arch}/{shape}/opt"] = o
                csv.add(f"roofline/{arch}/{shape}/OPT",
                        opt["compile_s"] * 1e6,
                        f"compute={o['compute_s']:.4f}s "
                        f"memory={o['memory_s']:.4f}s "
                        f"collective={o['collective_s']:.4f}s "
                        f"dom={o['dominant']} "
                        f"useful={o['useful_ratio']:.2f} "
                        f"dom_speedup={base_dom / max(opt_dom, 1e-12):.2f}x")
    return res
