"""Benchmark-regression gate: compare a fresh ``--quick`` run against the
committed baseline.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.run --quick      # writes results.json
    python -m benchmarks.check_regression                    # gate
    python -m benchmarks.check_regression --update-baseline  # bless results

(The XLA flag matters: the committed baseline is recorded on the forced
8-device CPU mesh CI uses, and bench_exec's stage counts — exact metrics —
depend on it.  Without the flag the gate fails spuriously on n_stages.)

The baseline (``benchmarks/artifacts/baseline_quick.json``) is committed so
a later PR cannot silently give back a perf win (ROADMAP: the sparse-DP
speedup at N ≥ 50).  Metrics are compared per kind:

* **exact** (default) — booleans, counts, and deterministic floats (miss /
  rejection rates, objectives, objective gaps, pruned fractions): equal to
  relative 1e-6.  Quick scenarios are fixed-seed and solver-deterministic,
  so these must not move at all; a drift is a behaviour change, not noise.
* **time** (leaf key ending ``_s`` / ``_us``) — wall-clock: fresh must be
  ≤ ``--time-tolerance`` × baseline.  Even min-of-N millisecond timings
  jitter ±40 % on one machine, so the default is 1.75 (pass ``1.25`` for a
  strict same-machine gate); CI passes a wider factor because shared
  runners are not the machine the baseline was recorded on.  Getting
  faster never fails the gate.
* **speedup** (leaf key containing ``speedup``) — machine-relative ratios,
  the real lock on the sparse-DP win: fresh must be ≥ 0.6 × baseline.
  These are ratios of timings taken in the same process, so they hold
  across machines and are the strict regression signal.
* **info** (leaf key ending ``_info``) — reported, never gated.  The exec
  engine's measured kernel walls and predicted-vs-measured error magnitudes
  land here: they track real-model CPU compute whose cross-machine spread
  exceeds any sane tolerance, so the gate checks only their *presence*
  (schema drift still fails) while the correctness booleans and launch
  counts they accompany are gated exactly.

Schema drift (a metric added or removed) fails the gate: update the
baseline deliberately with ``--update-baseline`` and commit the diff.

``--summary-md PATH`` appends a per-metric verdict table (baseline vs
current, class, verdict) in GitHub-flavoured markdown — CI points it at
``$GITHUB_STEP_SUMMARY`` so a red gate is readable from the checks page.
``--locks-only`` gates just the speedup-class locks present in both files:
the nightly workflow compares the *full* run against the quick baseline,
where exact metrics and schema legitimately differ but the machine-relative
speedup ratios must still hold.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts"
RESULTS = ARTIFACTS / "results.json"
BASELINE = ARTIFACTS / "baseline_quick.json"

TIME_TOLERANCE = 1.75     # fresh_time ≤ tol × baseline_time
SPEEDUP_FLOOR = 0.6       # fresh_speedup ≥ floor × baseline_speedup
EXACT_REL_TOL = 1e-6      # deterministic metrics: allow float-build jitter


def flatten(node, prefix: str = "") -> dict[str, object]:
    """Nested dicts/lists → {dotted.path: leaf} (lists indexed by position)."""
    out: dict[str, object] = {}
    if isinstance(node, dict):
        for key, val in node.items():
            out.update(flatten(val, f"{prefix}.{key}" if prefix else str(key)))
    elif isinstance(node, list):
        for i, val in enumerate(node):
            out.update(flatten(val, f"{prefix}[{i}]"))
    else:
        out[prefix] = node
    return out


def metric_kind(path: str) -> str:
    leaf = path.rsplit(".", 1)[-1]
    if leaf.endswith("_info"):
        return "info"
    if "speedup" in leaf:
        return "speedup"
    if leaf.endswith("_s") or leaf.endswith("_us") or leaf.endswith("_time"):
        return "time"
    return "exact"


def evaluate(baseline: dict, fresh: dict,
             time_tolerance: float = TIME_TOLERANCE,
             locks_only: bool = False) -> list[dict]:
    """Judge every metric path; one row per path (dict with ``path``,
    ``kind``, ``base``, ``new``, ``ok``, ``detail``).

    ``locks_only`` gates only the speedup-class locks present in *both*
    maps — the nightly mode, where the full (non ``--quick``) run is
    compared against the quick baseline: exact/time metrics and schema
    legitimately differ across modes, but the machine-relative speedup
    ratios must still hold.
    """
    rows: list[dict] = []
    for path in sorted(set(baseline) | set(fresh)):
        kind = metric_kind(path)
        if locks_only and kind != "speedup":
            continue
        if path not in fresh:
            if locks_only:
                continue
            rows.append({"path": path, "kind": kind,
                         "base": baseline[path], "new": None, "ok": False,
                         "detail": "missing (baseline has it)"})
            continue
        if path not in baseline:
            if locks_only:
                continue
            rows.append({"path": path, "kind": kind, "base": None,
                         "new": fresh[path], "ok": False,
                         "detail": "new, not in baseline "
                                   "(run --update-baseline)"})
            continue
        base, new = baseline[path], fresh[path]
        row = {"path": path, "kind": kind, "base": base, "new": new,
               "ok": True, "detail": ""}
        rows.append(row)
        if kind == "info":          # presence-only: value is never gated
            continue
        numeric = (isinstance(base, (int, float)) and
                   isinstance(new, (int, float)) and
                   not isinstance(base, bool) and not isinstance(new, bool))
        if not numeric:
            if base != new:
                row["ok"] = False
                row["detail"] = f"{base!r} -> {new!r}"
        elif kind == "time":
            if new > base * time_tolerance:
                row["ok"] = False
                row["detail"] = (f"{new:.6g}s > {time_tolerance:.2f}x "
                                 f"baseline {base:.6g}s")
        elif kind == "speedup":
            if new < base * SPEEDUP_FLOOR:
                row["ok"] = False
                row["detail"] = (f"speedup {new:.3g}x < "
                                 f"{SPEEDUP_FLOOR:.2f}x baseline "
                                 f"{base:.3g}x")
        else:
            if abs(new - base) > EXACT_REL_TOL * max(1.0, abs(base)):
                row["ok"] = False
                row["detail"] = (f"{base!r} -> {new!r} "
                                 f"(deterministic metric moved)")
    return rows


def compare(baseline: dict, fresh: dict,
            time_tolerance: float = TIME_TOLERANCE,
            locks_only: bool = False) -> list[str]:
    """All regressions between two flattened metric maps (empty = gate ok)."""
    return [f"{r['path']}: {r['detail']}"
            for r in evaluate(baseline, fresh, time_tolerance, locks_only)
            if not r["ok"]]


def _fmt(val: object) -> str:
    if isinstance(val, bool) or not isinstance(val, (int, float)):
        return repr(val)
    if isinstance(val, int):
        return str(val)
    return f"{val:.6g}"


def write_summary_md(rows: list[dict], path: pathlib.Path,
                     title: str = "Benchmark regression gate") -> None:
    """Append a per-metric verdict table (GitHub-flavoured markdown) —
    pointed at ``$GITHUB_STEP_SUMMARY`` this makes a gate failure readable
    from the PR checks page instead of a raw traceback.  Failures lead;
    the full table is collapsed behind ``<details>``.
    """
    failed = [r for r in rows if not r["ok"]]
    gated = [r for r in rows if r["kind"] != "info"]
    head = "| metric | class | baseline | current | verdict |\n|---|---|---|---|---|\n"

    def table(rs: list[dict]) -> str:
        return head + "\n".join(
            f"| `{r['path']}` | {r['kind']} | {_fmt(r['base'])} "
            f"| {_fmt(r['new'])} "
            f"| {'✅ ok' if r['ok'] else '❌ ' + r['detail']} |"
            for r in rs) + "\n"

    lines = [f"## {title}\n",
             f"**{'❌ FAILED' if failed else '✅ ok'}** — "
             f"{len(rows)} metrics ({len(gated)} gated, "
             f"{len(rows) - len(gated)} info-only), "
             f"{len(failed)} regression(s)\n"]
    if failed:
        lines.append(table(failed))
    lines.append("<details><summary>all metrics</summary>\n")
    lines.append(table(rows))
    lines.append("</details>\n")
    with path.open("a") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", type=pathlib.Path, default=RESULTS,
                    help="fresh --quick results.json")
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE,
                    help="committed baseline to gate against")
    ap.add_argument("--time-tolerance", type=float, default=TIME_TOLERANCE,
                    help="max allowed fresh/baseline wall-clock ratio "
                         "(use a wider factor on shared CI runners)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="bless the fresh results as the new baseline")
    ap.add_argument("--summary-md", type=pathlib.Path, default=None,
                    help="append a per-metric markdown verdict table here "
                         "(point at $GITHUB_STEP_SUMMARY in CI)")
    ap.add_argument("--locks-only", action="store_true",
                    help="gate only speedup-class locks present in both "
                         "baseline and results (nightly: full run vs the "
                         "quick baseline — schema/exact drift is expected)")
    args = ap.parse_args()

    results = json.loads(args.results.read_text())
    errors = {k: v["error"] for k, v in results.items()
              if isinstance(v, dict) and "error" in v}
    if errors:
        print("benchmark modules errored:", errors)
        sys.exit(1)

    if args.update_baseline:
        shutil.copyfile(args.results, args.baseline)
        print(f"baseline updated: {args.baseline} "
              f"({len(flatten(results))} metrics) — commit the diff")
        return

    baseline = json.loads(args.baseline.read_text())
    rows = evaluate(flatten(baseline), flatten(results),
                    time_tolerance=args.time_tolerance,
                    locks_only=args.locks_only)
    if args.summary_md is not None:
        title = ("Benchmark regression gate"
                 + (" (speedup locks only)" if args.locks_only else ""))
        write_summary_md(rows, args.summary_md, title=title)
    problems = [f"{r['path']}: {r['detail']}" for r in rows if not r["ok"]]
    what = "speedup locks" if args.locks_only else "metrics"
    if problems:
        print(f"benchmark regression gate FAILED ({len(problems)}):")
        for p in problems:
            print(f"  - {p}")
        print("if intentional: python -m benchmarks.check_regression "
              "--update-baseline && commit the baseline diff")
        sys.exit(1)
    print(f"benchmark regression gate ok "
          f"({len(rows)} {what} vs {args.baseline.name})")


if __name__ == "__main__":
    main()
