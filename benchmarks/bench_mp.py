"""Paper Fig. 9–13: OULD-MP (mobility prediction) under non-homogeneous
swarm motion, areas 100² and 500² m², LeNet and VGG-16 — and the Fig. 13
comparison against the offline-fixed distribution of [32].  Both strategies
come from the planner registry: ``ould-mp`` plans once over the predicted
horizon; the offline-fixed baseline is ``ould-ilp`` on the t=0 snapshot
held while the swarm moves.

Claims:
  M1  per-step latency of OULD-MP is stable across the horizon (one policy
      survives topology variation);
  M2  the offline-fixed baseline degrades/disconnects as UAVs drift
      (infinite latency steps ⇒ rejected requests), OULD-MP does not;
  M3  larger areas reduce interference-driven latency for high-memory
      networks (paper Fig. 10 vs 9).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import HorizonView, Problem, SnapshotView, get_planner

from .common import COMP_CAP, GFLOPS, HIGH_MEM, LOW_MEM, PROFILES, Csv, \
    make_network, timed


def _instance(model: str, n_uavs: int, mem: float, area: float, horizon: int,
              seed: int = 0) -> Problem:
    """The horizon instance: predicted (T, N, N) rates + hotspot sources."""
    mob = make_network(n_uavs, area, seed=seed, homogeneous=False)
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, 3, 4).astype(np.int64)  # hotspot sources
    rates = mob.predicted_rates(horizon)
    return Problem(PROFILES[model], np.full(n_uavs, mem),
                   np.full(n_uavs, COMP_CAP), rates, sources,
                   compute_speed=np.full(n_uavs, GFLOPS))


def run(csv: Csv) -> dict:
    res = {}
    mp_planner = get_planner("ould-mp", mip_rel_gap=1e-4, time_limit=30.0)
    for model, area, mem in [
        ("lenet", 100.0, HIGH_MEM), ("lenet", 100.0, LOW_MEM),
        ("lenet", 500.0, HIGH_MEM),
        ("vgg16", 100.0, HIGH_MEM), ("vgg16", 500.0, HIGH_MEM),
    ]:
        tag = (f"{model}_{int(area)}m_"
               f"{'hi' if mem == HIGH_MEM else 'lo'}mem")
        prob = _instance(model, 10, mem, area, horizon=6)
        plan, us = timed(mp_planner.plan, prob, HorizonView(prob.rates))
        lat = [e.avg_latency_per_request for e in plan.evaluate_per_step()]
        res[tag] = lat
        finite = [x for x in lat if np.isfinite(x)]
        csv.add(f"mp/{tag}", us,
                f"lat_steps={['%.3f' % x for x in lat]} "
                f"stable={max(finite) - min(finite) < 1.0 if finite else False}")

    # Fig. 13: OULD-MP vs offline-fixed [32] on a drifting swarm — the
    # baseline is the snapshot planner at t=0 with its placement held.
    prob = _instance("lenet", 10, HIGH_MEM, 300.0, horizon=10, seed=7)
    mp, us1 = timed(mp_planner.plan, prob, HorizonView(prob.rates))
    off_planner = get_planner("ould-ilp", mip_rel_gap=1e-4, time_limit=30.0)
    prob0 = dataclasses.replace(prob, rates=prob.rates[0])
    off, us2 = timed(off_planner.plan, prob0, SnapshotView(prob.rates[0]))
    mp_lat = [e.avg_latency_per_request for e in mp.evaluate_per_step()]
    off_lat = [e.avg_latency_per_request
               for e in off.evaluate_per_step(prob.rates)]
    mp_bad = sum(not np.isfinite(x) or x > 1e3 for x in mp_lat)
    off_bad = sum(not np.isfinite(x) or x > 1e3 for x in off_lat)
    csv.add("mp/vs_offline_fig13", us1 + us2,
            f"mp={mp.planner_name} offline={off.planner_name} "
            f"mp_outage_steps={mp_bad} offline_outage_steps={off_bad} "
            f"M2_mp_survives={mp_bad <= off_bad}")
    res["fig13"] = {"mp": mp_lat, "offline": off_lat}
    return res
