"""Paper Fig. 9–13: OULD-MP (mobility prediction) under non-homogeneous
swarm motion, areas 100² and 500² m², LeNet and VGG-16 — and the Fig. 13
comparison against the offline-fixed distribution of [32].

Claims:
  M1  per-step latency of OULD-MP is stable across the horizon (one policy
      survives topology variation);
  M2  the offline-fixed baseline degrades/disconnects as UAVs drift
      (infinite latency steps ⇒ rejected requests), OULD-MP does not;
  M3  larger areas reduce interference-driven latency for high-memory
      networks (paper Fig. 10 vs 9).
"""

from __future__ import annotations

import numpy as np

from repro.core import solve_offline_fixed, solve_ould_mp

from .common import (COMP_CAP, GFLOPS, HIGH_MEM, LOW_MEM, PROFILES, Csv,
                     make_network, timed)


def _mp(model: str, n_uavs: int, mem: float, area: float, horizon: int,
        seed: int = 0, solver: str = "ilp"):
    mob = make_network(n_uavs, area, seed=seed, homogeneous=False)
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, 3, 4).astype(np.int64)  # hotspot sources
    kw = dict(mem_cap=np.full(n_uavs, mem), comp_cap=np.full(n_uavs, COMP_CAP),
              sources=sources, mobility=mob, horizon=horizon,
              compute_speed=np.full(n_uavs, GFLOPS), solver=solver,
              mip_rel_gap=1e-4, time_limit=30.0)
    if solver == "dp":
        kw.pop("mip_rel_gap"), kw.pop("time_limit")
    return kw


def run(csv: Csv) -> dict:
    res = {}
    for model, area, mem, solver in [
        ("lenet", 100.0, HIGH_MEM, "ilp"), ("lenet", 100.0, LOW_MEM, "ilp"),
        ("lenet", 500.0, HIGH_MEM, "ilp"),
        ("vgg16", 100.0, HIGH_MEM, "ilp"), ("vgg16", 500.0, HIGH_MEM, "ilp"),
    ]:
        tag = (f"{model}_{int(area)}m_"
               f"{'hi' if mem == HIGH_MEM else 'lo'}mem")
        kw = _mp(model, 10, mem, area, horizon=6, solver=solver)
        mp, us = timed(solve_ould_mp, PROFILES[model], **kw)
        lat = [e.avg_latency_per_request for e in mp.per_step]
        res[tag] = lat
        finite = [x for x in lat if np.isfinite(x)]
        csv.add(f"mp/{tag}", us,
                f"lat_steps={['%.3f' % x for x in lat]} "
                f"stable={max(finite) - min(finite) < 1.0 if finite else False}")

    # Fig. 13: OULD-MP vs offline-fixed [32] on a drifting swarm
    kw = _mp("lenet", 10, HIGH_MEM, 300.0, horizon=10, seed=7)
    mp, us1 = timed(solve_ould_mp, PROFILES["lenet"], **kw)
    off, us2 = timed(solve_offline_fixed, PROFILES["lenet"], **kw)
    mp_lat = [e.avg_latency_per_request for e in mp.per_step]
    off_lat = [e.avg_latency_per_request for e in off.per_step]
    mp_bad = sum(not np.isfinite(x) or x > 1e3 for x in mp_lat)
    off_bad = sum(not np.isfinite(x) or x > 1e3 for x in off_lat)
    csv.add("mp/vs_offline_fig13", us1 + us2,
            f"mp_outage_steps={mp_bad} offline_outage_steps={off_bad} "
            f"M2_mp_survives={mp_bad <= off_bad}")
    res["fig13"] = {"mp": mp_lat, "offline": off_lat}
    return res
