"""Event-driven swarm serving simulator + incremental OULD re-solves."""

import numpy as np
import pytest

from repro.core import (IncrementalSolver, MultiGroupMobility, Problem,
                        RPGParams, evaluate, lenet_profile, rate_matrix,
                        solve_heuristic, solve_ould)
from repro.core.events import EventKind, EventQueue, churn_events, poisson_process
from repro.core.ould import Solution
from repro.core.profiles import LayerProfile, ModelProfile
from repro.runtime.queueing import DeadlineClass
from repro.runtime.swarm import (SimResult, SwarmScenario, _serve_once,
                                 _Simulation, _masked, _spb, build_event_tape,
                                 compare_policies, simulate)

MB = 1e6

SMALL = SwarmScenario(duration_ticks=60, arrival_rate_hz=0.3,
                      mtbf_s=60.0, mttr_s=20.0)


# ---------------------------------------------------------------------------
# event primitives
# ---------------------------------------------------------------------------

def test_poisson_process_deterministic_and_sorted():
    a = poisson_process(np.random.default_rng(7), 0.5, 100.0)
    b = poisson_process(np.random.default_rng(7), 0.5, 100.0)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all() and (a >= 0).all() and (a < 100.0).all()
    assert poisson_process(np.random.default_rng(0), 0.0, 100.0).size == 0


def test_event_queue_stable_ordering():
    q = EventQueue()
    q.push(1.0, EventKind.MOBILITY_TICK, 1)
    q.push(0.5, EventKind.ARRIVAL, 0)
    q.push(1.0, EventKind.EPOCH)          # same time, pushed later
    assert q.pop().kind == EventKind.ARRIVAL
    first, second = q.pop(), q.pop()
    assert first.kind == EventKind.MOBILITY_TICK   # insertion order on ties
    assert second.kind == EventKind.EPOCH
    assert not q


def test_churn_fail_rejoin_alternate_and_protect():
    evs = churn_events(np.random.default_rng(3), 6, 500.0, mtbf_s=50.0,
                       mttr_s=10.0, protected=frozenset({0, 1}))
    assert evs, "expected some churn on a 500 s horizon"
    assert all(e.node >= 2 for e in evs)
    per_node: dict = {}
    for e in evs:
        per_node.setdefault(e.node, []).append(e.kind)
    for kinds in per_node.values():
        for a, b in zip(kinds, kinds[1:]):
            assert a != b                # fail and rejoin strictly alternate
        assert kinds[0] == EventKind.NODE_FAIL


def test_multigroup_links_fade_and_window_deterministic():
    mob = MultiGroupMobility(RPGParams(n_uavs=10, area_m=500.0), n_groups=2,
                             seed=0)
    pos = mob.positions(120, seed=3)
    inter = mob.group_of[:, None] != mob.group_of[None, :]
    conn = np.array([(rate_matrix(pos[t])[inter] > 0).mean()
                     for t in range(0, 120, 10)])
    assert conn.min() == 0.0 and conn.max() == 1.0  # fades out AND in
    np.testing.assert_allclose(mob.positions(20, seed=3, t0=30),
                               mob.positions(50, seed=3)[30:])


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------

def test_simulator_deterministic_under_fixed_seed():
    a = simulate(SMALL, "incremental", seed=5)
    b = simulate(SMALL, "incremental", seed=5)
    np.testing.assert_array_equal(a.latencies, b.latencies)
    assert (a.served, a.missed, a.n_arrivals, a.n_never_admitted) == \
           (b.served, b.missed, b.n_arrivals, b.n_never_admitted)
    assert [e.objective for e in a.epochs] == [e.objective for e in b.epochs]


def test_same_event_tape_across_policies():
    res = compare_policies(SMALL, seed=1, policies=("incremental", "nearest"))
    a, b = res["incremental"], res["nearest"]
    assert a.n_arrivals == b.n_arrivals
    assert [e.tick for e in a.epochs] == [e.tick for e in b.epochs]
    assert [e.n_active for e in a.epochs] == [e.n_active for e in b.epochs]


@pytest.mark.parametrize("policy", ["incremental", "ould-mp", "nearest",
                                    "hrm", "nearest-hrm"])
def test_capacity_invariants_every_epoch(policy):
    r = simulate(SMALL, policy, seed=2)
    assert r.epochs, "simulation must hit at least one epoch boundary"
    assert all(e.feasible for e in r.epochs)
    assert all(e.n_admitted <= e.n_active for e in r.epochs)


def test_mp_beats_snapshot_ould_on_predicted_disconnections():
    """Two-group sweep, no churn: every disconnection is predictable, so
    OULD-MP must out-serve snapshot OULD on deadline misses (Fig. 13)."""
    scn = SwarmScenario(arrival_rate_hz=0.3)   # mobility fade only
    mp = simulate(scn, "ould-mp", seed=0)
    snap = simulate(scn, "incremental", seed=0)
    assert mp.deadline_miss_rate < snap.deadline_miss_rate


def test_policy_aliases_removed():
    """PR 2's deprecated aliases are gone: only canonical registry names."""
    for legacy in ("ould", "ould_mp", "nearest_hrm"):
        with pytest.raises(ValueError, match="unknown policy"):
            simulate(SMALL, legacy, seed=0)


# ---------------------------------------------------------------------------
# incremental solver
# ---------------------------------------------------------------------------

def _inc_setup(seed=0, n=10, requests=8):
    prof = lenet_profile()
    mob = MultiGroupMobility(RPGParams(n_uavs=n, area_m=300.0), n_groups=2,
                             seed=seed)
    pos = mob.positions(40, seed=seed + 1)
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 3, requests).astype(np.int64)
    inc = IncrementalSolver(prof, np.full(n, 192 * MB), np.full(n, 95e9),
                            np.full(n, 9.5e9), solver="dp")
    return prof, pos, src, inc


def test_warm_resolve_noop_keeps_everything():
    prof, pos, src, inc = _inc_setup()
    rates = rate_matrix(pos[0])
    sol0, _ = inc.solve(rates, src)
    sol1, st = inc.resolve(rates, src)
    assert st.n_replaced == 0 and st.n_kept == len(src)
    np.testing.assert_array_equal(sol0.assign, sol1.assign)
    assert sol1.objective == pytest.approx(sol0.objective, rel=1e-12)


def test_warm_resolve_matches_cold_objective_on_full_change():
    prof, pos, src, inc = _inc_setup()
    inc.solve(rate_matrix(pos[0]), src)
    new_rates = rate_matrix(pos[30])           # everything drifted
    warm, st = inc.resolve(new_rates, src)
    cold = solve_ould(Problem(prof, np.full(10, 192 * MB), np.full(10, 95e9),
                              new_rates, src, np.full(10, 9.5e9)),
                      solver="dp")
    assert st.n_kept == 0                      # all links moved
    np.testing.assert_array_equal(warm.assign, cold.assign)
    assert warm.objective == pytest.approx(cold.objective, rel=1e-12)


def test_warm_resolve_repacks_on_departure():
    """A departed stream's freed capacity must be re-offered: survivors
    sourced at (or placed on) its nodes re-place instead of keeping a stale
    offload.  Two streams share source node 0, which fits exactly one; when
    the locally-placed one departs, the offloaded survivor must come home."""
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, 1.0, 4.0) for j in range(4)),
        input_bytes=16.0)
    rng = np.random.default_rng(1)
    pos = rng.uniform(0, 60, (3, 3))
    pos[:, 2] = 50.0
    rates = rate_matrix(pos)
    inc = IncrementalSolver(prof, np.full(3, 40.0), np.full(3, 1e9),
                            solver="dp")
    src = np.zeros(2, np.int64)                 # both sourced at node 0
    sol0, _ = inc.solve(rates, src)
    assert (sol0.assign[0] == 0).all()          # stream 0 serves locally
    assert not (sol0.assign[1] == 0).all()      # stream 1 spilled elsewhere
    warm, st = inc.resolve(rates, src[1:], request_ids=[1])  # stream 0 gone
    assert st.n_replaced == 1                   # freed node 0 re-offered
    assert (warm.assign[0] == 0).all()          # survivor came home
    assert warm.objective == pytest.approx(0.0, abs=1e-12)


def test_warm_resolve_respects_alive_mask():
    prof, pos, src, inc = _inc_setup()
    rates = rate_matrix(pos[0])
    sol0, _ = inc.solve(rates, src)
    dead = int(sol0.assign[sol0.admitted].max())   # kill a used node
    alive = np.ones(10, bool)
    alive[dead] = False
    warm, _ = inc.resolve(rates, src, alive=alive)
    for r in range(len(src)):
        if warm.admitted[r]:
            assert dead not in warm.assign[r]


def test_constraint_cache_reused_for_ilp():
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, 1.0, [8.0, 4.0, 2.0, 1.0][j])
        for j in range(4)), input_bytes=16.0)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 80, (3, 3))
    pos[:, 2] = 50.0
    inc = IncrementalSolver(prof, np.full(3, 30.0), np.full(3, 1e9),
                            solver="ilp")
    src = np.arange(2, dtype=np.int64) % 3
    a, _ = inc.solve(rate_matrix(pos), src)
    assert len(inc.constraint_cache) == 1
    b, _ = inc.resolve(rate_matrix(pos) * 1.3, src)   # same shape → cache hit
    assert len(inc.constraint_cache) == 1
    assert a.objective == pytest.approx(b.objective / 1.0, rel=0.5)


# ---------------------------------------------------------------------------
# rejected-request accounting (the -1 sentinel)
# ---------------------------------------------------------------------------

def _tiny_problem():
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, 1.0, 4.0) for j in range(4)),
        input_bytes=16.0)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 80, (3, 3))
    pos[:, 2] = 50.0
    # 2 requests × 40B > 3 nodes × 20B ⇒ rejection guaranteed
    return Problem(prof, np.full(3, 20.0), np.full(3, 1e9),
                   rate_matrix(pos), np.zeros(2, np.int64))


@pytest.mark.parametrize("kind", ["nearest", "hrm", "nearest_hrm"])
def test_heuristic_rejected_rows_carry_sentinel(kind):
    sol = solve_heuristic(_tiny_problem(), kind)
    assert not sol.admitted.all()
    for r in np.flatnonzero(~sol.admitted):
        assert (sol.assign[r] == -1).all()
    assert evaluate(_tiny_problem(), sol).feasible


def test_solver_rejected_rows_carry_sentinel():
    prob = _tiny_problem()
    for sol in (solve_ould(prob), solve_ould(prob, solver="dp")):
        assert not sol.admitted.all()
        for r in np.flatnonzero(~sol.admitted):
            assert (sol.assign[r] == -1).all()


def test_evaluate_rejects_sentinel_marked_admitted():
    prob = _tiny_problem()
    bad = Solution(np.full((2, 4), -1, np.int64), 0.0, "feasible", 0.0,
                   np.ones(2, bool))
    with pytest.raises(AssertionError, match="sentinel"):
        evaluate(prob, bad)


# ---------------------------------------------------------------------------
# degraded views (StaleView / NoisyHorizonView) + executed-latency sampling
# ---------------------------------------------------------------------------

def test_stale_view_smoke_same_tape_different_decisions():
    """'stale:<k>' runs, keeps the event tape paired, and only changes what
    the planner sees (serving metrics may move; arrivals/epochs may not)."""
    import dataclasses
    fresh = simulate(SMALL, "incremental", seed=3)
    stale = simulate(dataclasses.replace(SMALL, view_degradation="stale:8"),
                     "incremental", seed=3)
    assert stale.n_arrivals == fresh.n_arrivals
    assert [e.tick for e in stale.epochs] == [e.tick for e in fresh.epochs]
    assert [e.n_active for e in stale.epochs] == \
           [e.n_active for e in fresh.epochs]
    assert stale.served > 0 and np.isfinite(stale.latencies).all()


def test_noisy_horizon_view_smoke_and_zero_noise_identity():
    import dataclasses
    noisy = simulate(dataclasses.replace(SMALL, view_degradation="noisy:0.4"),
                     "ould-mp", seed=3)
    assert noisy.served > 0
    # σ = 0 must be bit-identical to the undegraded run
    clean = simulate(SMALL, "ould-mp", seed=3)
    zero = simulate(dataclasses.replace(SMALL, view_degradation="noisy:0"),
                    "ould-mp", seed=3)
    np.testing.assert_array_equal(zero.latencies, clean.latencies)
    # snapshot planners ignore prediction noise entirely (measured, not
    # predicted): also bit-identical
    snap = simulate(dataclasses.replace(SMALL, view_degradation="noisy:0.4"),
                    "nearest", seed=3)
    ref = simulate(SMALL, "nearest", seed=3)
    np.testing.assert_array_equal(snap.latencies, ref.latencies)


def test_view_wrappers_contract():
    from repro.core import HorizonView, NoisyHorizonView, StaleView
    rates = np.abs(np.random.default_rng(0).normal(1e7, 1e6, (3, 4, 4)))
    rates[:, 0, 1] = 0.0                    # a disconnected pair
    hv = HorizonView(rates)
    nv = NoisyHorizonView.corrupt(hv, 0.3, seed=1)
    assert nv.kind == "horizon" and nv.noise_std == 0.3
    assert (nv.rates[:, 0, 1] == 0.0).all()   # noise never invents links
    assert not np.allclose(nv.rates[:, 1, 2], rates[:, 1, 2])
    sv = StaleView(rates[0], age_ticks=5)
    assert sv.kind == "snapshot" and sv.age_ticks == 5


def test_bad_degradation_spec_rejected():
    import dataclasses
    with pytest.raises(ValueError, match="degradation"):
        simulate(dataclasses.replace(SMALL, view_degradation="fog:1"),
                 "incremental", seed=0)


# ---------------------------------------------------------------------------
# queueing runtime (event tape, tail metrics, overload policies)
# ---------------------------------------------------------------------------

def test_event_tape_pairing_invariant():
    """Same seed ⇒ bit-identical stochastic input, policy-independent."""
    a, b = build_event_tape(SMALL, 9), build_event_tape(SMALL, 9)
    for key, arr in a.signature().items():
        np.testing.assert_array_equal(arr, b.signature()[key])
    other = build_event_tape(SMALL, 10).signature()
    assert any(not np.array_equal(v, other[k])
               for k, v in a.signature().items())
    # single deadline class: the class draw is skipped, tape stays legacy
    assert (a.signature()["klass"] == 0).all()


def test_event_tape_draws_classes_only_when_tiered():
    import dataclasses
    tiered = dataclasses.replace(SMALL, deadline_classes=(
        DeadlineClass("interactive", 0.8), DeadlineClass("batch", 6.0)))
    tape = build_event_tape(tiered, 4)
    ks = tape.signature()["klass"]
    assert set(np.unique(ks)) == {0, 1}
    # arrivals/sources unchanged vs the single-class tape: the class draw
    # must not perturb the rest of the rng stream retroactively
    single = build_event_tape(SMALL, 4)
    np.testing.assert_array_equal(tape.signature()["arrive_tick"],
                                  single.signature()["arrive_tick"])


def test_tail_latency_percentiles():
    lats = (np.arange(1000, dtype=float) + 1.0) / 1000.0
    r = SimResult("x", 1000, 0, 1000, 0, lats, [])
    assert r.p50_latency_s == pytest.approx(np.percentile(lats, 50.0))
    assert r.p99_latency_s == pytest.approx(np.percentile(lats, 99.0))
    assert r.p999_latency_s == pytest.approx(np.percentile(lats, 99.9))
    assert r.p50_latency_s < r.p99_latency_s < r.p999_latency_s
    empty = SimResult("x", 0, 0, 0, 0, np.zeros(0), [])
    assert empty.p50_latency_s == float("inf")
    assert empty.p999_latency_s == float("inf")


def test_miss_rate_decomposes_into_outage_and_over_deadline():
    r = simulate(SMALL, "incremental", seed=3)      # churn on ⇒ outages
    assert r.outages > 0
    assert r.missed >= r.outages
    assert r.deadline_miss_rate == pytest.approx(
        r.over_deadline_miss_rate + r.outage_rate)
    # frame conservation: every serve attempt is an outage, a completion,
    # a policy drop, or a queue rejection
    assert r.served == r.outages + r.latencies.size + r.dropped \
        + r.frames_rejected


def test_vectorized_serve_matches_scalar_reference():
    """The struct-of-arrays serve step must price each frame exactly like
    the scalar `_serve_once` reference (queueing adds wait on top of it).
    Bottleneck mode: its `_pending` carries the (base, service) pair this
    test audits; the per-hop twin is below."""
    import dataclasses
    prof = lenet_profile()
    scn = dataclasses.replace(SMALL, mtbf_s=float("inf"),
                              queue_model="bottleneck")
    sim = _Simulation(scn, "nearest", 11, prof, False)
    K, Ks = list(prof.output_vector()), prof.input_bytes
    comp = list(prof.compute_vector())
    checked = 0
    orig = sim.on_tick

    def spy(t):
        nonlocal checked
        rows = None
        if not sim._dirty:
            rows = sim.table.active_rows(t)
        orig(t)
        if sim._pending is None:
            return
        rows = sim.table.active_rows(t) if rows is None else rows
        spb_t = _spb(_masked(sim.rates_t[t], sim.alive))
        scalar = np.array([_serve_once(sim.table.path[r], int(sim.table.src[r]),
                                       spb_t, sim.alive, K, Ks, comp,
                                       sim.speed) for r in rows])
        got = np.sort(sim._pending["base"] + sim._pending["service"])
        np.testing.assert_allclose(got, np.sort(scalar[np.isfinite(scalar)]),
                                   rtol=1e-9)
        checked += len(got)

    sim.on_tick = spy
    q = sim.tape.queue()
    while q:
        ev = q.pop()
        if ev.kind == EventKind.MOBILITY_TICK:
            spy(ev.payload)
            sim._pending = None          # drop frames: pricing-only replay
        elif ev.kind == EventKind.ARRIVAL:
            sim.active[ev.payload] = sim.streams[ev.payload]
        elif ev.kind == EventKind.DEPARTURE:
            sim.active.pop(ev.payload, None)
            if sim.placed.pop(ev.payload, None) is not None:
                sim._dirty = True
        elif ev.kind == EventKind.EPOCH:
            sim.on_epoch(int(round(ev.time / scn.tick_s)))
    assert checked > 50


def test_perhop_schedule_sums_match_scalar_reference():
    """Per-hop twin of the test above: the hop schedule's total service
    (uplink + stage walls + boundary links) must equal the scalar
    `_serve_once` path latency at rtol 1e-9 — queueing only ever adds
    *wait* between hops, never changes the work."""
    import dataclasses
    prof = lenet_profile()
    scn = dataclasses.replace(SMALL, mtbf_s=float("inf"))
    sim = _Simulation(scn, "nearest", 11, prof, False)
    assert sim.perhop
    K, Ks = list(prof.output_vector()), prof.input_bytes
    comp = list(prof.compute_vector())
    checked = 0
    orig = sim.on_tick

    def spy(t):
        nonlocal checked
        rows = None
        if not sim._dirty:
            rows = sim.table.active_rows(t)
        orig(t)
        if sim._pending is None:
            return
        rows = sim.table.active_rows(t) if rows is None else rows
        spb_t = _spb(_masked(sim.rates_t[t], sim.alive))
        scalar = np.array([_serve_once(sim.table.path[r], int(sim.table.src[r]),
                                       spb_t, sim.alive, K, Ks, comp,
                                       sim.speed) for r in rows])
        got = np.sort(sim._pending["svc"].sum(axis=1))
        np.testing.assert_allclose(got, np.sort(scalar[np.isfinite(scalar)]),
                                   rtol=1e-9)
        checked += len(got)

    sim.on_tick = spy
    q = sim.tape.queue()
    while q:
        ev = q.pop()
        if ev.kind == EventKind.MOBILITY_TICK:
            spy(ev.payload)
            sim._pending = None          # drop frames: pricing-only replay
        elif ev.kind == EventKind.ARRIVAL:
            sim.active[ev.payload] = sim.streams[ev.payload]
        elif ev.kind == EventKind.DEPARTURE:
            sim.active.pop(ev.payload, None)
            if sim.placed.pop(ev.payload, None) is not None:
                sim._dirty = True
        elif ev.kind == EventKind.EPOCH:
            sim.on_epoch(int(round(ev.time / scn.tick_s)))
    assert checked > 50


def test_bottleneck_mode_bit_identical_to_pr6_seeds():
    """`queue_model="bottleneck"` is the frozen compatibility mode: on the
    fixed PR 6 seeds it must reproduce the pre-refactor results to the
    last bit (counters integer-equal, latency sums float-equal)."""
    import dataclasses
    scn = dataclasses.replace(SMALL, queue_model="bottleneck")
    r = simulate(scn, "nearest", seed=7)
    assert (r.served, r.missed, r.outages, r.dropped,
            r.frames_rejected) == (256, 27, 15, 0, 0)
    assert float(r.latencies.sum()) == 206.86428925120043
    r = simulate(scn, "incremental", seed=7)
    assert (r.served, r.missed, r.outages, r.dropped,
            r.frames_rejected) == (256, 51, 9, 0, 0)
    assert float(r.latencies.sum()) == 609.9276542507364


def test_perhop_collapses_to_bottleneck_when_uncontended():
    """With arrivals far apart every queue is empty, so the tandem network
    must price each frame exactly like the bottleneck model: base + wait +
    service == Σ hops at rtol 1e-9 (the ISSUE's equivalence acceptance)."""
    import dataclasses
    scn = SwarmScenario(duration_ticks=40, arrival_rate_hz=0.02,
                        mtbf_s=1e9, mttr_s=1.0)
    for pol in ("nearest", "incremental"):
        a = simulate(dataclasses.replace(scn, queue_model="bottleneck"),
                     pol, seed=3)
        b = simulate(scn, pol, seed=3)
        assert (a.served, a.missed, a.outages) == (b.served, b.missed,
                                                   b.outages)
        np.testing.assert_allclose(np.sort(b.latencies),
                                   np.sort(a.latencies), rtol=1e-9)


def test_perhop_sees_contention_bottleneck_misses():
    """On a churn tape with multi-node paths the tandem network queues
    frames at shared relays and uplinks the bottleneck model treats as
    deterministic — per-hop p99 must sit strictly above bottleneck p99."""
    import dataclasses
    rb = simulate(dataclasses.replace(SMALL, queue_model="bottleneck"),
                  "incremental", seed=7)
    rp = simulate(SMALL, "incremental", seed=7)
    fb = rb.latencies[np.isfinite(rb.latencies)]
    fp = rp.latencies[np.isfinite(rp.latencies)]
    assert np.percentile(fp, 99) > np.percentile(fb, 99)
    assert fp.sum() > fb.sum()


def test_drift_triggered_resolve_counts_and_cuts_misses():
    """`resolve_on_drift` re-solves between epochs when mean placement
    drift crosses the threshold: triggers are counted in SimResult, and
    on a churn-heavy tape with sparse fixed epochs the early re-solves
    must not lose to fixed-epoch-only re-solving on miss rate."""
    import dataclasses
    base = dataclasses.replace(SMALL, epoch_ticks=30, duration_ticks=90)
    fixed = simulate(base, "incremental", seed=5)
    assert fixed.drift_resolves == 0
    drift = simulate(dataclasses.replace(base, resolve_on_drift=0.05),
                     "incremental", seed=5)
    assert drift.drift_resolves > 0
    assert drift.loss_rate <= fixed.loss_rate


def _overload(**kw) -> SwarmScenario:
    """Arrival pressure ≥ 2× service capacity: slow nodes (0.5 GFLOPS ⇒
    multi-second stage walls) under a dense stream load."""
    import dataclasses
    return dataclasses.replace(
        SMALL, mtbf_s=float("inf"), arrival_rate_hz=0.8,
        hold_ticks_mean=40.0, gflops=5e8, deadline_s=4.0, **kw)


def test_drop_and_degrade_beat_no_policy_on_tail_latency():
    import dataclasses
    none = simulate(_overload(), "nearest", seed=6)
    drop = simulate(_overload(service_policy="fifo+drop"), "nearest", seed=6)
    degr = simulate(_overload(service_policy="fifo+degrade:0.2"), "nearest",
                    seed=6)
    assert none.wait_total_s > 0            # the overload is real
    assert drop.dropped > 0 and degr.degraded > 0
    assert drop.p99_latency_s < none.p99_latency_s
    assert degr.p99_latency_s < none.p99_latency_s
    # identical tape: per-policy arrival counts are paired
    assert none.n_arrivals == drop.n_arrivals == degr.n_arrivals


def test_queue_aware_admission_cuts_deadline_misses():
    blind = simulate(_overload(), "nearest", seed=3)
    aware = simulate(_overload(queue_aware_admission=True), "nearest", seed=3)
    assert sum(e.n_queue_rejected for e in aware.epochs) > 0
    assert aware.deadline_miss_rate <= blind.deadline_miss_rate
    assert blind.n_arrivals == aware.n_arrivals   # same tape


def test_deadline_classes_tier_the_miss_accounting():
    import dataclasses
    scn = _overload(service_policy="edf+drop")
    tiered = dataclasses.replace(scn, deadline_classes=(
        DeadlineClass("interactive", 1.0), DeadlineClass("batch", 30.0)))
    r = simulate(tiered, "nearest", seed=5)
    assert r.served > 0 and r.dropped > 0
    # the generous tier keeps the completion pool alive under overload
    assert r.latencies.size > 0


def test_executed_latency_sampling_smoke():
    """SwarmScenario(execute=True): measured stage walls replace the
    analytic compute term; latencies stay finite and strictly positive."""
    import dataclasses
    scn = dataclasses.replace(SMALL, duration_ticks=20, execute=True)
    r = simulate(scn, "incremental", seed=0)
    assert r.served > 0
    assert np.isfinite(r.latencies).all()
    assert (r.latencies > 0).all()
    # the analytic twin of the same tape serves the same number of frames
    analytic = simulate(dataclasses.replace(scn, execute=False),
                        "incremental", seed=0)
    assert analytic.served == r.served


def test_improvement_bound_invariants():
    """Slack-capacity DP bound: <= current cost everywhere, zero on
    non-admitted rows, drift >= 0 (core.ould.improvement_bound)."""
    from repro.core.ould import improvement_bound, placement_drift
    from repro.core import SnapshotView, get_planner

    rng = np.random.default_rng(0)
    mob = MultiGroupMobility(RPGParams(n_uavs=8, area_m=150.0,
                                       homogeneous=False), n_groups=2, seed=0)
    rates = rate_matrix(mob.positions(1)[0])
    sources = rng.integers(0, 3, 5).astype(np.int64)
    prob = Problem(lenet_profile(), np.full(8, 128 * MB), np.full(8, 95e9),
                   rates, sources, compute_speed=np.full(8, 9.5e9))
    plan = get_planner("ould-dp").plan(prob, SnapshotView(rates))
    assert plan.n_admitted > 0

    bound, current = improvement_bound(prob, plan.assign, plan.admitted)
    assert (bound <= current + 1e-12).all()
    assert (bound[~plan.admitted] == 0).all()
    assert (current[~plan.admitted] == 0).all()
    drift = placement_drift(prob, plan.assign, plan.admitted)
    assert (drift >= 0).all()
    np.testing.assert_allclose(drift, np.maximum(current - bound, 0.0))
    # sparse kernel stays a valid (possibly looser) bound
    b_sparse, c_sparse = improvement_bound(prob, plan.assign, plan.admitted,
                                           sparse_k=3)
    np.testing.assert_allclose(c_sparse, current)
    assert (b_sparse <= c_sparse + 1e-12).all()


def test_improvement_bound_detects_drifted_placement():
    """Crashing the rates a kept placement rides makes the slack-capacity
    re-place strictly cheaper: positive drift (the epoch keep-rule cost)."""
    from repro.core.ould import placement_drift
    from repro.core import SnapshotView, get_planner

    mob = MultiGroupMobility(RPGParams(n_uavs=8, area_m=150.0,
                                       homogeneous=False), n_groups=2, seed=0)
    rates = rate_matrix(mob.positions(1)[0])
    sources = np.zeros(4, np.int64)
    prob = Problem(lenet_profile(), np.full(8, 96 * MB), np.full(8, 95e9),
                   rates, sources, compute_speed=np.full(8, 9.5e9))
    plan = get_planner("ould-dp").plan(prob, SnapshotView(rates))
    assert plan.n_admitted > 0

    # degrade every link the committed paths actually use by 100x
    crashed = np.array(rates, copy=True)
    for r in range(4):
        if not plan.admitted[r]:
            continue
        prev = int(prob.sources[r])
        for node in plan.assign[r]:
            if node != prev:
                crashed[prev, node] /= 100.0
                prev = int(node)
    drifted = Problem(prob.profile, prob.mem_cap, prob.comp_cap, crashed,
                      prob.sources, compute_speed=prob.compute_speed)
    drift = placement_drift(drifted, plan.assign, plan.admitted)
    assert drift[plan.admitted].max() > 0


def test_simulate_tracks_improvement_bound():
    """track_improvement_bound=True logs the per-epoch drift the keep rule
    accumulates; the hook never changes serving."""
    import dataclasses
    scn = dataclasses.replace(SMALL, duration_ticks=40,
                              track_improvement_bound=True)
    r = simulate(scn, "incremental", seed=0)
    assert r.placement_drift_s.size == len(r.epochs)
    assert (r.placement_drift_s >= 0).all()
    assert r.max_placement_drift_s >= r.mean_placement_drift_s >= 0
    for e in r.epochs:
        assert e.drift_max_s <= e.drift_total_s + 1e-12
    baseline = simulate(dataclasses.replace(scn,
                                            track_improvement_bound=False),
                        "incremental", seed=0)
    assert baseline.served == r.served
    assert baseline.mean_placement_drift_s == 0.0


def test_executed_loopback_transport_samples_substrate():
    """execute=True + transport='loopback': the sim ships each newly-seen
    boundary activation through worker processes and reports realized
    substrate bandwidth per link; serving itself stays tape-identical."""
    import dataclasses
    scn = dataclasses.replace(SMALL, n_uavs=8, duration_ticks=16,
                              epoch_ticks=8, execute=True,
                              transport="loopback")
    r = simulate(scn, "incremental", seed=0)
    assert r.transport == "loopback"
    assert r.served > 0
    assert r.link_bytes_per_s, "no substrate links sampled"
    assert all(bw > 0 for bw in r.link_bytes_per_s.values())
    inproc = simulate(dataclasses.replace(scn, transport="inproc"),
                      "incremental", seed=0)
    assert inproc.transport == "inproc" and inproc.link_bytes_per_s == {}
    assert inproc.served == r.served


def test_churn_rejoin_fires_warm_start_in_executed_mode():
    """NODE_REJOIN in executed mode pre-compiles the live plan's stage
    signature (ExecutionEngine.warm_start) before the next epoch's plan
    lands; the analytic twin of the same tape never warm-starts, and the
    side effect is compile-cache-only — serving stays tape-identical."""
    import dataclasses
    scn = dataclasses.replace(SMALL, mtbf_s=40.0, mttr_s=10.0,
                              execute=True)
    r = simulate(scn, "incremental", seed=3)
    assert r.warm_starts >= 1, "no rejoin warmed the execution engine"
    analytic = simulate(dataclasses.replace(scn, execute=False),
                        "incremental", seed=3)
    assert analytic.warm_starts == 0
    assert analytic.served == r.served
