"""OULD / OULD-MP optimization: optimality, constraints, admission."""

import itertools

import numpy as np
import pytest

from repro.core import (Problem, RPGMobility, RPGParams, evaluate,
                        rate_matrix, solve_heuristic, solve_ould)
from repro.core.profiles import LayerProfile, ModelProfile


def toy_profile(m=4, mem=10.0, comp=5.0):
    outs = [8.0, 4.0, 2.0, 1.0, 1.0, 1.0][:m]
    layers = tuple(LayerProfile(f"l{j}", mem, comp, outs[j]) for j in range(m))
    return ModelProfile("toy", layers, input_bytes=16.0)


def toy_problem(n=3, r=2, mem_cap=30.0, seed=0, m=4):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 80, (n, 3))
    pos[:, 2] = 50.0
    return Problem(toy_profile(m), np.full(n, mem_cap), np.full(n, 1e9),
                   rate_matrix(pos), np.arange(r) % n)


def brute_force(prob):
    spb = prob.transfer_cost()
    K = prob.profile.output_vector()
    mem = prob.profile.memory_vector()
    N, M, R = prob.n_nodes, prob.n_layers, prob.n_requests
    best = np.inf
    for a in itertools.product(range(N), repeat=R * M):
        a = np.array(a).reshape(R, M)
        load = np.zeros(N)
        for r in range(R):
            for j in range(M):
                load[a[r, j]] += mem[j]
        if (load > prob.mem_cap + 1e-9).any():
            continue
        cost = 0.0
        for r in range(R):
            src = int(prob.sources[r])
            if a[r, 0] != src:
                cost += prob.profile.input_bytes * spb[src, a[r, 0]]
            for j in range(M - 1):
                if a[r, j + 1] != a[r, j]:
                    cost += K[j] * spb[a[r, j], a[r, j + 1]]
        best = min(best, cost)
    return best


def test_ilp_matches_bruteforce():
    prob = toy_problem()
    sol = solve_ould(prob)
    assert sol.status == "optimal"
    assert sol.objective == pytest.approx(brute_force(prob), rel=1e-6)


def test_gamma_relaxation_exact():
    """γ continuous in [0,1] must not change the optimum (big-M argument)."""
    for seed in range(3):
        prob = toy_problem(seed=seed)
        a = solve_ould(prob, gamma_relaxed=True).objective
        b = solve_ould(prob, gamma_relaxed=False).objective
        assert a == pytest.approx(b, rel=1e-9)


def test_tight_constraints_equivalent():
    prob = toy_problem(seed=1)
    a = solve_ould(prob, tight=True).objective
    b = solve_ould(prob, tight=False).objective
    assert a == pytest.approx(b, rel=1e-9)


def test_capacity_constraints_respected():
    prob = toy_problem(n=4, r=3, mem_cap=25.0)
    sol = solve_ould(prob)
    ev = evaluate(prob, sol)
    assert ev.feasible


def test_admission_sheds_when_over_capacity():
    # 2 requests × 4 layers × 10B > 3 nodes × 20B ⇒ at most 1 admitted
    prob = toy_problem(n=3, r=2, mem_cap=20.0)
    sol = solve_ould(prob)
    assert sol.status.startswith("rejected")
    assert sol.n_admitted == 1
    assert evaluate(prob, sol).feasible


def test_dp_optimal_when_capacity_slack():
    prob = toy_problem(n=3, r=1, mem_cap=1e9)
    ilp = solve_ould(prob)
    dp = solve_ould(prob, solver="dp")
    assert dp.objective == pytest.approx(ilp.objective, rel=1e-6)


def test_heuristics_feasible_and_dominated():
    prob = toy_problem(n=4, r=3, mem_cap=25.0, seed=2)
    opt = solve_ould(prob)
    for kind in ("nearest", "hrm", "nearest_hrm"):
        sol = solve_heuristic(prob, kind)
        ev = evaluate(prob, sol)
        assert ev.feasible
        if sol.n_admitted == opt.n_admitted == prob.n_requests:
            assert evaluate(prob, opt).comm_latency_s <= ev.comm_latency_s + 1e-9


def test_exactly_one_constraint():
    prob = toy_problem()
    sol = solve_ould(prob)
    # every admitted request has every layer on exactly one node (assign is
    # a function) and the path starts from a real node id
    assert sol.assign.shape == (prob.n_requests, prob.n_layers)
    assert (sol.assign >= 0).all() and (sol.assign < prob.n_nodes).all()


def test_ould_mp_avoids_predicted_disconnection():
    """A pair that disconnects mid-horizon must not carry any transfer."""
    prof = toy_profile(m=2, mem=10.0)
    # node 2 drifts out of range at t=1; OULD-MP must not route via node 2
    rates = np.full((2, 3, 3), 1e8)
    for t in range(2):
        np.fill_diagonal(rates[t], np.inf)
    rates[1, 0, 2] = rates[1, 2, 0] = 0.0
    rates[1, 1, 2] = rates[1, 2, 1] = 0.0
    prob = Problem(prof, np.full(3, 10.0), np.full(3, 1e9), rates,
                   np.zeros(1, np.int64))
    sol = solve_ould(prob)
    assert 2 not in sol.assign[0]


def test_mobility_rates_deterministic():
    mob = RPGMobility(RPGParams(n_uavs=5), seed=42)
    a = mob.predicted_rates(3, seed=7)
    b = RPGMobility(RPGParams(n_uavs=5), seed=42).predicted_rates(3, seed=7)
    np.testing.assert_allclose(a, b)


# ---------------------------------------------------------------------------
# capacity repair rules
# ---------------------------------------------------------------------------

def _contended_problem():
    """Three ~equal requests over caps where the halving repair's geometric
    overshoot excludes placements the gentle rule keeps reachable."""
    layers = tuple(LayerProfile(f"l{j}", m, 5.0, o) for j, (m, o) in
                   enumerate([(14.0, 6.0), (15.0, 2.0), (14.0, 2.0)]))
    prof = ModelProfile("toy", layers, input_bytes=16.0)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 80, (3, 3))
    pos[:, 2] = 50.0
    return Problem(prof, np.array([50.0, 31.0, 39.0]), np.full(3, 1e9),
                   rate_matrix(pos), np.array([1, 2, 0], np.int64))


def test_gentle_repair_admits_strictly_more_under_contention():
    """`capacity_repair="gentle"` sheds `load − min hosted layer` (with a
    largest-layer peel when that cannot strictly shrink) instead of halving
    — on this crafted contention scenario it admits strictly more requests,
    while the default stays the pinned halving rule."""
    prob = _contended_problem()
    halve = solve_ould(prob, solver="dp")
    default = solve_ould(prob, solver="dp", capacity_repair="halve")
    gentle = solve_ould(prob, solver="dp", capacity_repair="gentle")
    np.testing.assert_array_equal(halve.assign, default.assign)
    assert int(gentle.admitted.sum()) > int(halve.admitted.sum())
    # gentle's extra admissions still respect the joint per-node load
    mem = np.asarray(prob.profile.memory_vector())
    load = np.zeros(prob.n_nodes)
    for r in range(prob.n_requests):
        if gentle.admitted[r]:
            for j, i in enumerate(gentle.assign[r]):
                load[i] += mem[j]
    assert (load <= prob.mem_cap + 1e-9).all()


def test_capacity_repair_validated_and_threads_through_solvers():
    prob = _contended_problem()
    with pytest.raises(ValueError, match="capacity_repair"):
        solve_ould(prob, solver="dp", capacity_repair="nope")
    from repro.core import IncrementalSolver
    inc = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                            solver="dp", capacity_repair="gentle")
    sol, _ = inc.solve(prob.rates, prob.sources)
    gentle = solve_ould(prob, solver="dp", capacity_repair="gentle")
    assert int(sol.admitted.sum()) == int(gentle.admitted.sum())
