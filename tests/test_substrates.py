"""Unit tests: profiles, optimizer, sharding rules, radio model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lenet_profile, lm_profile, vgg16_profile
from repro.core.radio import RadioParams, rate_matrix
from repro.optim import AdamWConfig
from repro.optim import adamw


def test_lenet_profile_structure():
    p = lenet_profile()
    assert p.num_layers == 7                       # paper: LeNet = 7 units
    assert p.total_memory < 512e6                  # fits a high-mem node
    assert all(ly.output_bytes > 0 for ly in p.layers)


def test_vgg16_profile_structure():
    p = vgg16_profile()
    assert p.num_layers == 18                      # paper: VGG-16 = 18 units
    assert p.total_memory > 512e6                  # cannot fit any node
    # feature maps shrink through pooling: late conv outputs < early ones
    assert p.layers[-2].output_bytes < p.layers[0].output_bytes


def test_lm_profile_flops_scale_linearly_in_seq():
    kw = dict(n_layers=4, d_model=256, n_heads=4, n_kv=4, d_ff=512,
              vocab=1000)
    a = lm_profile("a", seq=128, **kw)
    b = lm_profile("b", seq=256, **kw)
    # attention adds a superlinear component; everything else is linear
    assert 2.0 <= b.total_flops / a.total_flops <= 4.0


def test_rate_monotone_in_distance():
    pos = np.zeros((4, 3))
    pos[:, 2] = 50
    pos[1, 0], pos[2, 0], pos[3, 0] = 30, 90, 280
    r = rate_matrix(pos, RadioParams())
    assert r[0, 1] > r[0, 2] > r[0, 3] > 0


def test_rate_zero_beyond_range():
    pos = np.zeros((2, 3))
    pos[1, 0] = 500  # beyond max_range 300
    r = rate_matrix(pos, RadioParams())
    assert r[0, 1] == 0.0


def test_adamw_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    s = [float(adamw.schedule(cfg, jnp.int32(t))) for t in (1, 5, 10, 50, 100)]
    assert s[0] < s[1] < s[2]                      # warmup rises
    assert s[2] == pytest.approx(1e-3, rel=1e-5)   # peak at warmup end
    assert s[3] > s[4]                             # cosine decays
    assert s[4] >= cfg.lr * cfg.min_lr_frac - 1e-9


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_frac=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                      warmup_steps=0, total_steps=10, min_lr_frac=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    _, _, metrics = adamw.update(cfg, {"w": jnp.full(3, 1e6)}, state, params)
    assert float(metrics["grad_norm"]) > 1e6  # raw norm reported


def test_sharding_rules_divisibility_guard():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import sharding as sh
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    params = {
        "embed": {"table": jax.ShapeDtypeStruct((512, 64), jnp.float32)},
        "blocks": [{"attn": {"wqkv": jax.ShapeDtypeStruct((2, 64, 96), jnp.float32)},
                    "norm1": {"scale": jax.ShapeDtypeStruct((64,), jnp.float32)}}],
        "lm_head": jax.ShapeDtypeStruct((64, 512), jnp.float32),
    }
    specs = sh.param_pspecs(params, mesh, sh.MeshAxes())
    # 1-sized axes always divide: full specs expected
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["blocks"][0]["attn"]["wqkv"] == P(None, "data", "model")
    assert specs["blocks"][0]["norm1"]["scale"] == P(None)


def test_sharding_no_duplicate_axis_use():
    from jax.sharding import Mesh
    from repro.parallel import sharding as sh
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    # a square param where both dims match the same rule axis
    params = {"mlp": {"w_in": jax.ShapeDtypeStruct((64, 64), jnp.float32)}}
    spec = sh.param_pspecs(params, mesh, sh.MeshAxes())["mlp"]["w_in"]
    flat = [a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))]
    assert len(flat) == len(set(flat))
