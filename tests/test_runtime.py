"""Runtime substrates: checkpoint/restart, failure injection, elastic
re-placement, straggler detection, data pipeline resume, serving."""

import dataclasses
import tempfile

import jax
import numpy as np

import repro.configs as C
from repro.checkpointing import AsyncCheckpointer, CheckpointManager
from repro.core.profiles import lm_profile
from repro.data import DataConfig, DataLoader
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, elastic, train_loop
from repro.runtime.serve import ServeConfig, Server

CFG = C.get_config("internlm2_1p8b").reduced(n_layers=2, d_model=64,
                                             vocab=512)


def _dcfg():
    return DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=4)


def test_checkpoint_roundtrip_and_atomicity():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": [np.ones(3), {"c": np.int32(7)}]}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3):
            mgr.save(s, tree, extra={"next_step": s + 1})
        assert mgr.all_steps() == [2, 3]  # keep=2 GC'd step 1
        restored, extra = mgr.restore(3, tree)
        np.testing.assert_allclose(restored["a"], tree["a"])
        np.testing.assert_allclose(restored["b"][0], tree["b"][0])
        assert extra["next_step"] == 4


def test_async_checkpointer_snapshot_isolation():
    arr = np.zeros(4, np.float32)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        ck = AsyncCheckpointer(mgr)
        ck.save(0, {"w": arr})
        arr += 99.0  # mutate after snapshot — save must hold the old value
        ck.wait()
        restored, _ = mgr.restore(0, {"w": arr})
        np.testing.assert_allclose(restored["w"], np.zeros(4))


def test_train_resume_exact():
    """Crash at step 7 then restart: losses must continue the same stream."""
    with tempfile.TemporaryDirectory() as d:
        lcfg = train_loop.LoopConfig(total_steps=10, ckpt_every=5, ckpt_dir=d)
        tcfg = TrainConfig(remat=False,
                           optimizer=AdamWConfig(warmup_steps=2,
                                                 total_steps=10))
        ref = train_loop.run(CFG, tcfg, dataclasses.replace(
            lcfg, ckpt_dir=d + "/ref"), _dcfg())

        fired = []

        def fail_at(s):
            if s == 7 and not fired:
                fired.append(s)
                return True
            return False

        out = train_loop.run_with_restarts(CFG, tcfg, lcfg, _dcfg(),
                                           fail_at=fail_at)
        assert out["restarts"] == 1
        # post-restart losses match the uninterrupted run bit-for-bit-ish
        np.testing.assert_allclose(out["losses"][-3:], ref["losses"][-3:],
                                   rtol=1e-5)


def test_data_loader_resume_deterministic():
    cfg = _dcfg()
    l1 = DataLoader(cfg, start_step=0)
    batches = [next(l1) for _ in range(5)]
    l1.close()
    l2 = DataLoader(cfg, start_step=3)
    b3 = next(l2)
    l2.close()
    np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])


def test_data_loader_host_sharding_partitions():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8)
    h0 = DataLoader(cfg, host_id=0, num_hosts=2)
    h1 = DataLoader(cfg, host_id=1, num_hosts=2)
    a, b = next(h0), next(h1)
    h0.close(), h1.close()
    assert a["tokens"].shape == (4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_straggler_detector():
    det = train_loop.StragglerDetector(train_loop.LoopConfig())
    for _ in range(10):
        det.observe(0, 1.0)
    assert det.observe(11, 10.0)          # 10x slower step flagged
    assert not det.observe(12, 1.0)


def test_elastic_mesh_plan():
    p = elastic.plan_elastic_mesh(256)
    assert (p.data, p.model) == (16, 16)
    p2 = elastic.plan_elastic_mesh(240, model_parallel=16)
    assert p2.devices <= 240 and p2.model == 16
    p3 = elastic.plan_elastic_mesh(12, model_parallel=16)
    assert p3.devices <= 12


def test_elastic_replan_routes_around_failure():
    prof = lm_profile("toy", n_layers=8, d_model=256, n_heads=4, n_kv=4,
                      d_ff=512, vocab=1000, seq=128)
    per_node_mem = prof.total_memory / 2.5  # force ≥3 nodes
    stages = elastic.replan_placement(prof, n_groups=4,
                                      hbm_bytes=per_node_mem,
                                      flops_budget=1e18,
                                      failed=np.array([False, True, False,
                                                       False]))
    assert all(s.node != 1 for s in stages)
    assert stages[0].layer_start == 0
    assert stages[-1].layer_end == prof.num_layers


def test_checkpoint_restore_with_new_sharding():
    """Elastic path: restore onto explicit (single-device) shardings."""
    params = init_params(jax.random.PRNGKey(0), CFG)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(0, params)
        dev = jax.devices()[0]
        shardings = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(dev), params)
        restored, _ = mgr.restore(0, params, shardings=shardings)
        a = jax.tree.leaves(params)[0]
        b = jax.tree.leaves(restored)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_server_generate_deterministic():
    params = init_params(jax.random.PRNGKey(0), CFG)
    srv = Server(CFG, params, ServeConfig(max_len=48))
    prompts = np.random.default_rng(0).integers(0, CFG.vocab, (2, 8),
                                                dtype=np.int32)
    o1 = srv.generate(prompts, steps=6)
    o2 = srv.generate(prompts, steps=6)
    np.testing.assert_array_equal(o1, o2)
    assert o1.shape == (2, 6)


def test_grad_compression_training_still_converges():
    tcfg = TrainConfig(remat=False, grad_compression=True,
                       optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=30))
    with tempfile.TemporaryDirectory() as d:
        lcfg = train_loop.LoopConfig(total_steps=25, ckpt_every=100,
                                     ckpt_dir=d)
        out = train_loop.run(CFG, tcfg, lcfg, _dcfg())
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])
