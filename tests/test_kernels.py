"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import chunked, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssm_scan import ssd_scan_pallas

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D,causal,window,off", [
    (2, 128, 128, 4, 2, 64, True, None, 0),
    (1, 100, 100, 3, 1, 32, True, None, 0),
    (2, 64, 192, 4, 4, 64, True, None, 128),
    (1, 256, 256, 8, 2, 64, True, 64, 0),
    (2, 128, 128, 4, 2, 64, False, None, 0),
    (1, 64, 64, 2, 2, 128, True, None, 0),
])
def test_flash_attention(B, Sq, Skv, Hq, Hkv, D, causal, window, off, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    o_ref = ref.attention(q, k, v, causal=causal, window=window,
                          kv_offset=off)
    o_pal = flash_attention(q, k, v, causal=causal, window=window,
                            kv_offset=off, block_q=32, block_k=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Smax,Hq,Hkv,D,ln", [
    (2, 256, 4, 2, 64, 100), (3, 100, 6, 6, 32, 100),
    (2, 512, 8, 2, 128, 511), (1, 64, 4, 1, 64, 64),
])
def test_decode_attention(B, Smax, Hq, Hkv, D, ln, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, Smax, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, Smax, Hkv, D), dtype)
    o_ref = ref.decode_attention(q, kc, vc, ln)
    o_pal = decode_attention(q, kc, vc, ln, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **tol(dtype))


def test_decode_attention_per_seq_lengths():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (3, 4, 32))
    kc = jax.random.normal(ks[1], (3, 128, 2, 32))
    vc = jax.random.normal(ks[2], (3, 128, 2, 32))
    lens = jnp.array([5, 77, 128], jnp.int32)
    o_ref = ref.decode_attention(q, kc, vc, lens)
    o_pal = decode_attention(q, kc, vc, lens, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 37, 256), (2, 8, 64), (1, 1, 512)])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],)) * 0.1 + 1
    o_ref = ref.rmsnorm(x, s)
    o_pal = rmsnorm(x, s, block_rows=16, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("chunk", [16, 32, 40, 96])
@pytest.mark.parametrize("B,S,H,P,N", [(2, 96, 3, 16, 8), (1, 64, 1, 8, 4)])
def test_ssd_pallas(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, H)) + 2.0)
    b = jax.random.normal(ks[2], (B, S, H, N)) * 0.3
    c = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    h0 = jax.random.normal(ks[4], (B, H, P, N)) * 0.2
    y_ref, h_ref = ref.ssd_scan(x, a, b, c, h0)
    y_pal, h_pal = ssd_scan_pallas(x, a, b, c, h0, chunk=chunk,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("chunk", [7, 16, 48, 64])
def test_ssd_chunked_xla(chunk):
    ks = jax.random.split(KEY, 4)
    B, S, H, P, N = 2, 48, 3, 8, 4
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, H)) * 0.5 + 2.0)
    b = jax.random.normal(ks[2], (B, S, H, N)) * 0.3
    c = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    y0, h0f = ref.ssd_scan(x, a, b, c)
    y1, h1f = chunked.ssd_scan_chunked(x, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(h0f), np.asarray(h1f), rtol=2e-5,
                               atol=2e-5)


def test_mlstm_chunked_matches_sequential():
    ks = jax.random.split(KEY, 5)
    B, S, H, P = 2, 64, 3, 8
    q = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ig = jax.random.normal(ks[3], (B, S, H)) * 0.5
    fg = jax.random.normal(ks[4], (B, S, H)) * 0.5 + 3.0
    y0, _ = ref.mlstm_scan(q, k, v, ig, fg)
    y1, _ = chunked.mlstm_chunked(q, k, v, ig, fg, chunk=16)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4,
                               atol=1e-4)
