"""Sparse k-candidate DP (`ould-dp-sparse`): equivalence with the dense DP.

The contract under test (ISSUE 3 / DESIGN §2):
* k ≥ N ⇒ bit-identical assignments, admission and objective to ``ould-dp``;
* default k (⌈√N⌉) ⇒ the *same admission set* on fixed seeds (the fallback
  ladder re-runs a rejected request with k doubled, dense last) and a small
  (≤ 5 %) objective gap;
* the per-source stage cache inside the placer is invisible: clearing it
  before every placement must not change a single path or cost;
* the ``IncrementalSolver`` warm path re-places touched requests with the
  same pruned kernel and reproduces the cold sparse solve when everything
  is re-placed.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (IncrementalSolver, Problem, SnapshotView,
                        available_planners, default_sparse_k, get_planner,
                        lenet_profile, rate_matrix, solve_ould)
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.ould import _SparsePlacer
from repro.core.profiles import LayerProfile, ModelProfile

MB = 1e6


def _swarm(n=50, requests=16, seed=0, area=300.0, mem_mb=512.0,
           comp=95e9, hotspots=5):
    mob = RPGMobility(RPGParams(n_uavs=n, area_m=area, homogeneous=True),
                      seed=seed)
    rates = rate_matrix(mob.positions(1, seed=seed)[0])
    rng = np.random.default_rng(seed)
    src = rng.integers(0, min(hotspots, n), requests).astype(np.int64)
    return Problem(lenet_profile(), np.full(n, mem_mb * MB),
                   np.full(n, comp), rates, src, np.full(n, 9.5e9))


def _tight(n=12, requests=8, seed=0, mem_cap=30.0):
    """Toy instance with real contention: repairs, spreads and rejections."""
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, 1.0, [8.0, 4.0, 2.0, 1.0][j])
        for j in range(4)), input_bytes=16.0)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 120, (n, 3))
    pos[:, 2] = 50.0
    src = rng.integers(0, n, requests).astype(np.int64)
    return Problem(prof, np.full(n, mem_cap), np.full(n, 40.0),
                   rate_matrix(pos), src)


# ---------------------------------------------------------------------------
# dense equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bit_identical_to_dense_at_k_ge_n(seed):
    for prob in (_swarm(n=20, requests=8, seed=seed),
                 _tight(seed=seed)):
        dense = solve_ould(prob, solver="dp")
        sparse = solve_ould(prob, solver="dp-sparse",
                            sparse_k=prob.n_nodes)
        np.testing.assert_array_equal(sparse.assign, dense.assign)
        np.testing.assert_array_equal(sparse.admitted, dense.admitted)
        assert sparse.objective == dense.objective


@pytest.mark.parametrize("n,requests,seeds", [
    (50, 16, (0, 1, 2, 3)),
    (64, 24, (0, 1, 2)),
    (128, 32, (0, 1, 2)),
])
def test_default_k_equal_admission_and_small_gap(n, requests, seeds):
    for seed in seeds:
        prob = _swarm(n=n, requests=requests, seed=seed)
        dense = solve_ould(prob, solver="dp")
        sparse = solve_ould(prob, solver="dp-sparse")
        np.testing.assert_array_equal(sparse.admitted, dense.admitted)
        if dense.objective > 0:
            gap = abs(sparse.objective - dense.objective) / dense.objective
            assert gap <= 0.05, f"seed={seed}: gap {gap:.4f}"
        assert sparse.dp_stats is not None
        assert sparse.dp_stats.k == default_sparse_k(n)


def test_fallback_ladder_preserves_admission_at_tiny_k():
    """k=1 prunes aggressively; the ladder (k doubling, dense last resort)
    must still admit exactly what the dense solver admits."""
    for seed in range(4):
        prob = _tight(seed=seed)
        dense = solve_ould(prob, solver="dp", max_path_cost=1e6)
        sparse = solve_ould(prob, solver="dp-sparse", sparse_k=1,
                            max_path_cost=1e6)
        np.testing.assert_array_equal(sparse.admitted, dense.admitted)


def test_ladder_escalates_off_dead_link_without_admission_bar():
    """Two radio clusters joined by a single bridge node: with small k the
    bridge is crowded out of the candidate sets by feasible near nodes, so
    the pruned DP only sees a ``_BIG``-priced route.  The ladder must widen
    k (no ``max_path_cost`` required) until it finds the finite bridge path
    the dense DP finds."""
    rng = np.random.default_rng(0)
    nA, nB = 10, 9
    posA = np.column_stack([rng.uniform(0, 60, nA), rng.uniform(0, 60, nA),
                            np.full(nA, 50.0)])
    bridge = np.array([[250.0, 30.0, 50.0]])
    posB = np.column_stack([rng.uniform(440, 500, nB),
                            rng.uniform(0, 60, nB), np.full(nB, 50.0)])
    pos = np.vstack([posA, bridge, posB])      # bridge idx 10, B = 11..19
    n = pos.shape[0]
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, [1.0, 1.0, 1.0, 100.0][j],
                     [8.0, 4.0, 2.0, 1.0][j]) for j in range(4)),
        input_bytes=16.0)
    comp = np.full(n, 50.0)
    comp[nA + 1:] = 200.0      # the final layer only fits in cluster B
    prob = Problem(prof, np.full(n, 100.0), comp, rate_matrix(pos),
                   np.zeros(2, np.int64))
    dense = solve_ould(prob, solver="dp")
    sparse = solve_ould(prob, solver="dp-sparse", sparse_k=4)
    assert dense.objective < 1.0               # finite route via the bridge
    assert sparse.objective == pytest.approx(dense.objective)
    assert sparse.dp_stats.n_escalations > 0   # the ladder actually widened
    np.testing.assert_array_equal(sparse.admitted, dense.admitted)


def test_sparse_stats_telemetry():
    prob = _swarm(n=50, requests=16)
    sparse = solve_ould(prob, solver="dp-sparse")
    st = sparse.dp_stats
    assert st is not None and st.k == default_sparse_k(50)
    assert 0.0 <= st.pruned_fraction < 1.0
    assert st.n_escalations >= 0 and st.n_dense_fallback >= 0
    assert solve_ould(prob, solver="dp").dp_stats is None


# ---------------------------------------------------------------------------
# the stage cache is invisible (white-box)
# ---------------------------------------------------------------------------

def _place_all(prob, k, clear_cache):
    spb = prob.transfer_cost()
    prof = prob.profile
    mem_left = prob.mem_cap.astype(float).copy()
    comp_left = prob.comp_cap.astype(float).copy()
    placer = _SparsePlacer(spb, prof.output_vector(), prof.input_bytes,
                           prof.memory_vector(), prof.compute_vector(),
                           mem_left, comp_left, None, k=k,
                           max_path_cost=1e6)
    out = []
    for r in range(prob.n_requests):
        if clear_cache:
            placer._cache.clear()
        path, cost = placer.place(int(prob.sources[r]))
        admitted = path is not None and cost <= 1e6
        if admitted:
            placer.commit(path)
        out.append((None if path is None else path.tolist(), cost, admitted))
    return out, placer.n_cache_hits


@pytest.mark.parametrize("seed", range(5))
def test_stage_cache_replay_is_exact(seed):
    """Same paths, costs and admissions with the per-source cache replayed
    or cleared before every placement — contention included (repairs,
    escalations, feasibility flips)."""
    for prob, k in ((_tight(n=14, requests=12, seed=seed), 2),
                    (_swarm(n=40, requests=20, seed=seed, hotspots=3), 6)):
        cached, hits = _place_all(prob, k, clear_cache=False)
        fresh, no_hits = _place_all(prob, k, clear_cache=True)
        assert cached == fresh
        assert no_hits == 0


def test_stage_cache_actually_hits():
    prob = _swarm(n=50, requests=24, hotspots=3)
    _, hits = _place_all(prob, default_sparse_k(50), clear_cache=False)
    assert hits > 0      # hotspot sources repeat: replay must kick in


# ---------------------------------------------------------------------------
# warm (IncrementalSolver) path
# ---------------------------------------------------------------------------

def test_warm_sparse_resolve_matches_cold_sparse():
    """Big drift ⇒ every request re-placed ⇒ the warm re-solve must equal a
    cold dp-sparse solve on the drifted topology (same order, same residual
    sequence, same pruned kernel)."""
    prob = _swarm(n=40, requests=16, seed=2)
    mob = RPGMobility(RPGParams(n_uavs=40, area_m=300.0, homogeneous=True),
                      seed=2)
    pos = mob.positions(40, seed=5)
    inc = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                            prob.compute_speed, solver="dp-sparse")
    inc.solve(prob.rates, prob.sources)
    for t in (20, 39):
        drift = rate_matrix(pos[t])
        warm, stats = inc.resolve(drift, prob.sources)
        cold = solve_ould(dataclasses.replace(prob, rates=drift),
                          solver="dp-sparse")
        assert stats.k == default_sparse_k(40)
        np.testing.assert_array_equal(warm.admitted, cold.admitted)
        assert warm.objective == pytest.approx(cold.objective, rel=1e-12)


def test_warm_sparse_keeps_placements_without_drift():
    prob = _swarm(n=40, requests=16)
    inc = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                            prob.compute_speed, solver="dp-sparse")
    cold, _ = inc.solve(prob.rates, prob.sources)
    warm, stats = inc.resolve(prob.rates, prob.sources)
    assert stats.n_kept == int(cold.admitted.sum())
    assert stats.n_replaced == prob.n_requests - stats.n_kept
    np.testing.assert_array_equal(warm.assign, cold.assign)
    assert warm.solver == "dp-sparse-warm"


def test_warm_sparse_admission_matches_warm_dense():
    """On a fixed drift sequence the sparse warm loop admits the same
    streams as the dense warm loop (the ladder guarantee, composed with
    keep/re-place)."""
    prob = _swarm(n=50, requests=20, seed=3)
    mob = RPGMobility(RPGParams(n_uavs=50, area_m=300.0, homogeneous=True),
                      seed=3)
    pos = mob.positions(30, seed=7)
    dense = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                              prob.compute_speed, solver="dp")
    sparse = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                               prob.compute_speed, solver="dp-sparse")
    dense.solve(prob.rates, prob.sources)
    sparse.solve(prob.rates, prob.sources)
    for t in (10, 29):
        drift = rate_matrix(pos[t])
        wd, _ = dense.resolve(drift, prob.sources)
        ws, _ = sparse.resolve(drift, prob.sources)
        assert ws.n_admitted == wd.n_admitted


# ---------------------------------------------------------------------------
# planner registry plumbing
# ---------------------------------------------------------------------------

def test_registry_exposes_sparse_planners():
    assert {"ould-dp-sparse", "incremental-sparse"} <= set(available_planners())
    prob = _swarm(n=30, requests=8)
    plan = get_planner("ould-dp-sparse").plan(prob, SnapshotView(prob.rates))
    assert plan.planner_name == "ould-dp-sparse"
    assert plan.solve_stats is not None and plan.solve_stats.k > 0
    ref = solve_ould(prob, solver="dp-sparse")
    np.testing.assert_array_equal(plan.assign, ref.assign)


def test_sparse_k_option_threads_through_registry():
    prob = _swarm(n=30, requests=8)
    plan = get_planner("ould-dp-sparse", sparse_k=30).plan(
        prob, SnapshotView(prob.rates))
    dense = solve_ould(prob, solver="dp")
    np.testing.assert_array_equal(plan.assign, dense.assign)
    assert plan.objective == dense.objective


def test_incremental_sparse_pins_engine_against_option_sweep():
    # Registry sweeps pass one uniform option dict (solver="dp" included);
    # the name must still pin the sparse engine.
    planner = get_planner("incremental-sparse", solver="dp", sparse_k=6)
    assert planner.solver == "dp-sparse"
    assert planner.sparse_k == 6
    prob = _swarm(n=30, requests=8)
    plan = planner.plan(prob, SnapshotView(prob.rates))
    assert plan.planner_name == "incremental-sparse"
    assert plan.solve_stats.k == 6


def test_ould_mp_can_run_the_sparse_engine():
    prob = _swarm(n=30, requests=8)
    mob = RPGMobility(RPGParams(n_uavs=30, area_m=300.0, homogeneous=True),
                      seed=0)
    horizon = mob.predicted_rates(4, seed=1)
    hp = dataclasses.replace(prob, rates=horizon)
    from repro.core import HorizonView
    plan = get_planner("ould-mp", solver="dp-sparse").plan(
        hp, HorizonView(horizon))
    ref = solve_ould(hp, solver="dp-sparse")
    np.testing.assert_array_equal(plan.assign, ref.assign)
    assert plan.objective == ref.objective


def test_swarm_scenario_sparse_knob_plumbs_to_planner():
    from repro.runtime.serve import AdmissionController
    ctrl = AdmissionController("incremental-sparse", solver="dp",
                               sparse_k=5)
    assert ctrl.planner.solver == "dp-sparse"
    assert ctrl.planner.sparse_k == 5
