"""shard_map expert-parallel MoE vs the dense oracle — on a real 4-device
mesh (subprocess: device count must be set before jax initializes)."""

import subprocess
import sys

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import Mesh
import repro.configs as C
from repro.models import moe as moe_mod
from repro.parallel import sharding as sh
moe_mod_min = 0

mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
axes = sh.MeshAxes(data=("data",), model="model")
cfg = C.get_config("granite_moe_3b").reduced(d_model=32, experts=4)
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=3, top_k=2, capacity_factor=8.0))
p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.5
y_ref, aux_ref = moe_mod.moe_apply(p, dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, impl="einsum")), x)
moe_mod.SHARD_MAP_MIN_TOKENS = 0  # force the shard_map path at test scale
sh.set_active_mesh(mesh, axes)
cfg_sm = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, impl="shard_map"))
y_sm, aux_sm = jax.jit(lambda p, x: moe_mod.moe_apply(p, cfg_sm, x))(p, x)
# grads must flow through the shard_map path (psum/all_gather transposes)
g = jax.grad(lambda p: moe_mod.moe_apply(p, cfg_sm, x)[0].sum())(p)
gn = sum(float(jnp.abs(t).sum()) for t in jax.tree.leaves(g))
err = np.abs(np.asarray(y_ref) - np.asarray(y_sm)).max()
assert err < 2e-5, err
# aux uses per-shard statistics (GShard semantics) — close, not identical
assert abs(float(aux_ref) - float(aux_sm)) < 5e-2
assert gn > 0.0 and np.isfinite(gn)
print("OK")
"""


def test_shard_map_moe_matches_dense():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
