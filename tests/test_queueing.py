"""Queueing runtime: vectorized kernel exactness, overload policies,
deadline classes, and persistent per-node state."""

import numpy as np
import pytest

from repro.runtime.queueing import (DEFAULT_CLASSES, NodeQueues, QueueOutcome,
                                    ServicePolicy, fifo_advance_kernel,
                                    policy_advance_kernel, tail_percentiles)


def _brute_force_fifo(node, arrival, service, free_at):
    """Reference: one-server-per-node simulation, frame at a time."""
    free = free_at.copy()
    start = np.zeros(len(node))
    finish = np.zeros(len(node))
    for i in range(len(node)):
        start[i] = max(arrival[i], free[node[i]])
        finish[i] = start[i] + service[i]
        free[node[i]] = finish[i]
    return start, finish


def _random_window(rng, n, n_nodes):
    node = np.sort(rng.integers(0, n_nodes, n))
    arrival = np.sort(rng.uniform(0, 10, n))          # any non-decreasing tape
    service = rng.uniform(0.01, 2.0, n)
    free = rng.uniform(0, 5, n_nodes)
    return node, arrival, service, free


# ---------------------------------------------------------------------------
# the vectorized kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fifo_kernel_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    node, arrival, service, free = _random_window(rng, 200, 5)
    start, finish = fifo_advance_kernel(node, arrival, service, free)
    ref_start, ref_finish = _brute_force_fifo(node, arrival, service, free)
    np.testing.assert_allclose(start, ref_start, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(finish, ref_finish, rtol=1e-12, atol=1e-12)


def test_fifo_kernel_empty_and_single():
    start, finish = fifo_advance_kernel(np.zeros(0, np.int64), np.zeros(0),
                                        np.zeros(0), np.zeros(3))
    assert start.size == 0 and finish.size == 0
    start, finish = fifo_advance_kernel(np.array([2]), np.array([1.0]),
                                        np.array([0.5]), np.array([0., 0., 9.]))
    assert start[0] == 9.0 and finish[0] == 9.5   # waits out the backlog


def test_fifo_kernel_throughput_1e6():
    """The vectorized kernel is what makes 10⁵–10⁶-frame scenarios feasible:
    a million frames must advance in well under a second."""
    import time
    rng = np.random.default_rng(0)
    node, arrival, service, free = _random_window(rng, 1_000_000, 16)
    t0 = time.perf_counter()
    start, finish = fifo_advance_kernel(node, arrival, service, free)
    wall = time.perf_counter() - t0
    assert np.isfinite(finish).all() and (finish >= start).all()
    assert wall < 1.0, f"vectorized kernel too slow: {wall:.2f}s for 1e6"


def test_policy_none_sequential_matches_vectorized():
    """The sequential policy sweep and the vectorized kernel agree when no
    reneging applies (deadlines far away) — they price the same queue."""
    rng = np.random.default_rng(7)
    node, arrival, service, free = _random_window(rng, 300, 4)
    deadline = arrival + 1e9
    out = policy_advance_kernel(node, arrival, service, deadline, free,
                                ServicePolicy("fifo", "drop"))
    start, finish = fifo_advance_kernel(node, arrival, service, free)
    assert out.completed.all() and not out.dropped.any()
    np.testing.assert_allclose(out.start_s, start, rtol=1e-12)
    np.testing.assert_allclose(out.finish_s, finish, rtol=1e-12)


# ---------------------------------------------------------------------------
# overload policies
# ---------------------------------------------------------------------------

def _overloaded_window(n=40):
    """One node, frames arriving together, each 1 s of service, 3 s deadline:
    only the first few can make it — the rest are overload."""
    node = np.zeros(n, np.int64)
    arrival = np.zeros(n)
    service = np.ones(n)
    deadline = np.full(n, 3.0)
    return node, arrival, service, deadline


def test_drop_policy_drops_late_frames_without_consuming_service():
    node, arrival, service, deadline = _overloaded_window()
    out = policy_advance_kernel(node, arrival, service, deadline,
                                np.zeros(1), ServicePolicy("fifo", "drop"))
    # starts run 0,1,2,3 — a start strictly past the 3 s deadline drops
    assert out.completed.sum() == 4 and out.dropped.sum() == len(node) - 4
    assert out.finish_s[out.completed].max() == 4.0   # drops freed no time
    assert (out.service_used_s[out.dropped] == 0).all()
    assert np.isinf(out.wait_s[out.dropped]).all()


def test_degrade_policy_serves_light_variant():
    node, arrival, service, deadline = _overloaded_window(8)
    out = policy_advance_kernel(node, arrival, service, deadline,
                                np.zeros(1),
                                ServicePolicy("fifo", "degrade", 0.25))
    assert out.completed.all()            # degrade never drops
    assert out.degraded.sum() > 0
    # degraded frames consumed factor × service
    np.testing.assert_allclose(out.service_used_s[out.degraded], 0.25)
    # queue drains faster than the none policy would have
    _, finish_none = fifo_advance_kernel(node, arrival, service, np.zeros(1))
    assert out.finish_s.max() < finish_none.max()


def test_reject_policy_turns_frames_away_at_arrival():
    node, arrival, service, deadline = _overloaded_window()
    out = policy_advance_kernel(node, arrival, service, deadline,
                                np.zeros(1), ServicePolicy("fifo", "reject"))
    # projected finish k+1 ≤ 3 admits exactly 3 frames
    assert out.completed.sum() == 3 and out.rejected.sum() == len(node) - 3
    assert not out.dropped.any()
    assert (out.service_used_s[out.rejected] == 0).all()


def test_drop_vs_reject_head_vs_arrival_semantics():
    """Drop checks the *start* against the deadline (the frame sat in the
    queue first); reject checks the projected *finish* on arrival — so
    reject is strictly more conservative on the same window."""
    node, arrival, service, deadline = _overloaded_window()
    drop = policy_advance_kernel(node, arrival, service, deadline,
                                 np.zeros(1), ServicePolicy("fifo", "drop"))
    rej = policy_advance_kernel(node, arrival, service, deadline,
                                np.zeros(1), ServicePolicy("fifo", "reject"))
    assert rej.completed.sum() <= drop.completed.sum()


# ---------------------------------------------------------------------------
# NodeQueues — persistent state, disciplines, counters
# ---------------------------------------------------------------------------

def test_node_queues_carry_backlog_across_windows():
    q = NodeQueues(2, ServicePolicy("fifo", "none"))
    out1 = q.advance(np.array([0, 0]), np.array([0.0, 0.0]),
                     np.array([2.0, 2.0]), np.array([1e9, 1e9]))
    np.testing.assert_allclose(out1.finish_s, [2.0, 4.0])
    np.testing.assert_allclose(q.backlog_s(1.0), [3.0, 0.0])
    # window 2 arrives at t=1: node 0 still busy until 4
    out2 = q.advance(np.array([0]), np.array([1.0]), np.array([0.5]),
                     np.array([1e9]))
    assert out2.start_s[0] == 4.0 and out2.wait_s[0] == 3.0
    assert q.n_enqueued == 3 and q.n_completed == 3


def test_edf_discipline_orders_by_deadline_within_window():
    q = NodeQueues(1, ServicePolicy("edf", "none"))
    # emission order: loose deadline first — EDF must serve the tight one first
    out = q.advance(np.array([0, 0]), np.array([0.0, 0.0]),
                    np.array([1.0, 1.0]), np.array([9.0, 2.0]))
    assert out.start_s[1] == 0.0 and out.start_s[0] == 1.0

    fifo = NodeQueues(1, ServicePolicy("fifo", "none"))
    out_f = fifo.advance(np.array([0, 0]), np.array([0.0, 0.0]),
                         np.array([1.0, 1.0]), np.array([9.0, 2.0]))
    assert out_f.start_s[0] == 0.0 and out_f.start_s[1] == 1.0


def test_edf_with_drop_saves_tight_deadlines_fifo_loses():
    """Two frames, the tight-deadline one emitted last: FIFO+drop loses it,
    EDF+drop serves it first and drops the loose one only if needed."""
    node = np.array([0, 0])
    arrival = np.zeros(2)
    service = np.ones(2)
    deadline = np.array([10.0, 0.5])       # frame 1 is tight, emitted second
    fifo = NodeQueues(1, ServicePolicy("fifo", "drop"))
    out_f = fifo.advance(node, arrival, service, deadline)
    assert bool(out_f.completed[0]) and bool(out_f.dropped[1])
    edf = NodeQueues(1, ServicePolicy("edf", "drop"))
    out_e = edf.advance(node, arrival, service, deadline)
    assert bool(out_e.completed[1]) and bool(out_e.completed[0])


def test_outcome_order_matches_emission_order_after_internal_sort():
    """advance() sorts internally (by node / deadline) but must hand results
    back aligned with the caller's emission order."""
    rng = np.random.default_rng(5)
    n = 64
    node = rng.integers(0, 3, n)           # deliberately unsorted
    arrival = np.zeros(n)
    service = rng.uniform(0.1, 0.5, n)
    q = NodeQueues(3, ServicePolicy("fifo", "none"))
    out = q.advance(node, arrival, service, np.full(n, 1e9))
    # reconstruct per-node FIFO by emission order and compare
    for nd in range(3):
        idx = np.flatnonzero(node == nd)
        expected_start = np.concatenate(
            [[0.0], np.cumsum(service[idx])[:-1]])
        np.testing.assert_allclose(out.start_s[idx], expected_start,
                                   rtol=1e-12, atol=1e-12)


def test_counters_accumulate():
    q = NodeQueues(1, ServicePolicy("fifo", "drop"))
    node, arrival, service, deadline = _overloaded_window(10)
    q.advance(node, arrival, service, deadline)
    assert q.n_enqueued == 10
    assert q.n_completed + q.n_dropped == 10
    assert q.n_dropped > 0 and q.n_rejected == 0


# ---------------------------------------------------------------------------
# policy parsing + deadline classes + percentiles
# ---------------------------------------------------------------------------

def test_service_policy_parse():
    assert ServicePolicy.parse("fifo") == ServicePolicy("fifo", "none")
    assert ServicePolicy.parse("edf+drop") == ServicePolicy("edf", "drop")
    p = ServicePolicy.parse("fifo+degrade:0.5")
    assert p.overload == "degrade" and p.degrade_factor == 0.5
    with pytest.raises(ValueError, match="discipline"):
        ServicePolicy.parse("lifo")
    with pytest.raises(ValueError, match="overload"):
        ServicePolicy.parse("fifo+explode")
    with pytest.raises(ValueError, match="parameter"):
        ServicePolicy.parse("fifo+drop:0.5")
    with pytest.raises(ValueError, match="degrade_factor"):
        ServicePolicy("fifo", "degrade", 1.5)


def test_default_deadline_classes_are_ordered_tiers():
    tiers = [c.deadline_s for c in DEFAULT_CLASSES]
    assert tiers == sorted(tiers) and len(DEFAULT_CLASSES) == 3


def test_tail_percentiles_guards_and_values():
    empty = tail_percentiles(np.zeros(0))
    assert all(np.isinf(v) for v in empty.values())
    only_inf = tail_percentiles(np.array([np.inf, np.inf]))
    assert all(np.isinf(v) for v in only_inf.values())
    lat = np.arange(1, 1001, dtype=float)   # 1..1000
    p = tail_percentiles(np.concatenate([lat, [np.inf]]))
    assert p["p50_s"] == pytest.approx(np.percentile(lat, 50))
    assert p["p99_s"] == pytest.approx(np.percentile(lat, 99))
    assert p["p999_s"] == pytest.approx(np.percentile(lat, 99.9))
    assert p["p50_s"] < p["p99_s"] < p["p999_s"]


def test_queue_outcome_fields_consistent():
    node, arrival, service, deadline = _overloaded_window(6)
    out = policy_advance_kernel(node, arrival, service, deadline,
                                np.zeros(1), ServicePolicy("fifo", "drop"))
    assert isinstance(out, QueueOutcome)
    # exactly one of completed / dropped / rejected per frame
    states = (out.completed.astype(int) + out.dropped.astype(int)
              + out.rejected.astype(int))
    assert (states == 1).all()


# ---------------------------------------------------------------------------
# tandem path kernel — per-hop queueing
# ---------------------------------------------------------------------------

from repro.runtime.queueing import (PathOutcome, PathQueues,  # noqa: E402
                                    link_resource, n_path_resources,
                                    path_advance_kernel, path_policy_sweep,
                                    path_sweep_reference)


def _random_tape(rng, n_frames=400, n_hops=6, n_nodes=5):
    """Synthetic multi-hop tape: random resources over the combined
    compute+link space, ~25 % padded hops, overlapping arrivals."""
    n_res = n_path_resources(n_nodes)
    res = rng.integers(0, n_res, (n_frames, n_hops))
    res[rng.random((n_frames, n_hops)) < 0.25] = -1
    service = rng.uniform(0.01, 0.5, (n_frames, n_hops))
    arrival = np.sort(rng.uniform(0, 20, n_frames))
    free = rng.uniform(0, 2, n_res)
    return res, service, arrival, free


def test_link_resource_layout_is_a_bijection():
    n = 7
    a, b = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    ids = link_resource(n, a, b).ravel()
    assert ids.min() == n and ids.max() == n_path_resources(n) - 1
    assert np.unique(ids).size == n * n          # every directed link distinct


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_path_kernel_exact_vs_python_sweep(seed):
    """The vectorized hop-major kernel reproduces the scalar sweep on
    synthetic multi-hop tapes, at the same exactness bar as the bottleneck
    kernel's brute-force fixture (segmented cumsum rounds differently from
    the sequential max/add by ~1 ulp per segment)."""
    rng = np.random.default_rng(seed)
    res, service, arrival, free = _random_tape(rng)
    st, fin, fr = path_advance_kernel(res, service, arrival, free)
    st_r, fin_r, fr_r = path_sweep_reference(res, service, arrival, free)
    np.testing.assert_allclose(st, st_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(fin, fin_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(fr, fr_r, rtol=1e-12, atol=1e-12)


def test_path_kernel_exact_vs_sweep_with_priority():
    """EDF in-wave order (priority = absolute deadline) matches too."""
    rng = np.random.default_rng(4)
    res, service, arrival, free = _random_tape(rng, n_frames=200)
    prio = arrival + rng.uniform(0.5, 5.0, arrival.shape)
    st, fin, fr = path_advance_kernel(res, service, arrival, free, prio)
    st_r, fin_r, fr_r = path_sweep_reference(res, service, arrival, free,
                                             prio)
    np.testing.assert_allclose(st, st_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(fin, fin_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(fr, fr_r, rtol=1e-12, atol=1e-12)


def test_path_kernel_does_not_mutate_free():
    rng = np.random.default_rng(9)
    res, service, arrival, free = _random_tape(rng, n_frames=50)
    snap = free.copy()
    path_advance_kernel(res, service, arrival, free)
    np.testing.assert_array_equal(free, snap)


def test_path_kernel_tandem_cascade_single_chain():
    """One frame through 3 hops: each hop starts at the previous finish
    (or the server's free time, whichever is later)."""
    res = np.array([[0, link_resource(2, 0, 1), 1]])
    svc = np.array([[1.0, 0.5, 2.0]])
    free = np.zeros(n_path_resources(2))
    free[1] = 5.0                       # node 1 busy until t=5
    st, fin, _ = path_advance_kernel(res, svc, np.array([0.0]), free)
    np.testing.assert_allclose(st[0], [0.0, 1.0, 5.0])
    np.testing.assert_allclose(fin[0], [1.0, 1.5, 7.0])


def test_shared_relay_contention_serializes():
    """Two frames crossing the same relay node: the second waits out the
    first at the shared hop — the contention bottleneck-mode cannot see."""
    relay = 2
    res = np.array([[0, relay], [1, relay]])
    svc = np.array([[0.1, 1.0], [0.1, 1.0]])
    st, fin, _ = path_advance_kernel(res, svc, np.zeros(2),
                                     np.zeros(n_path_resources(3)))
    # both reach the relay at 0.1; one serves 0.1→1.1, the other 1.1→2.1
    assert {round(float(st[0, 1]), 9), round(float(st[1, 1]), 9)} \
        == {0.1, 1.1}


def test_path_policy_sweep_flags_are_exclusive_and_consistent():
    rng = np.random.default_rng(11)
    res, service, arrival, free = _random_tape(rng, n_frames=150)
    ddl = arrival + rng.uniform(0.1, 1.0, arrival.shape)
    for spec in ("fifo+drop", "fifo+reject", "edf+degrade:0.25"):
        pol = ServicePolicy.parse(spec)
        st, fin, used, info = path_policy_sweep(res, service, arrival, ddl,
                                                free, pol)
        assert not (info["dropped"] & info["rejected"]).any()
        # rejected frames never consumed any hop
        assert (used[info["rejected"]] == 0).all()
        if pol.overload == "degrade":
            assert not info["dropped"].any() and not info["rejected"].any()


def test_path_queues_carry_backlog_and_count():
    q = PathQueues(2, ServicePolicy("fifo", "none"))
    res = np.array([[0, link_resource(2, 0, 1), 1]])
    out = q.advance(res, np.array([[1.0, 0.5, 2.0]]), np.zeros(1),
                    np.array([1e9]))
    assert isinstance(out, PathOutcome)
    np.testing.assert_allclose(out.lat_s, [3.5])
    np.testing.assert_allclose(out.done_s, [3.5])
    # backlog spans the combined space: node 0, link 0→1, node 1
    b = q.backlog_s(0.0)
    assert b.shape == (n_path_resources(2),)
    np.testing.assert_allclose(b[[0, 1]], [1.0, 3.5])
    np.testing.assert_allclose(b[link_resource(2, 0, 1)], 1.5)
    snap = q.snapshot()
    assert snap["queue.completed"] == 1
    assert snap["queue.max_link_demand_s"] == 0.5
    # empty window is a no-op
    empty = q.advance(np.zeros((0, 3), np.int64), np.zeros((0, 3)),
                      np.zeros(0), np.zeros(0))
    assert empty.lat_s.size == 0 and q.n_enqueued == 1
