"""Batched jitted DP kernel (``batch_solve=True``): bit-identity and the
padding/bucketing contract.

The contract under test (ISSUE 8 / DESIGN §8):
* the batched epoch solve is bit-identical to the sequential
  ``ould-dp-sparse`` request loop — admission, assignment AND objective —
  on fixed seeds across sizes, including contended instances where the
  fallback ladder handles every request the batched pass rejects;
* ``_sparse_select_batch`` rows equal S scalar ``_sparse_select`` calls;
* ``batch_dp.solve_batch`` equals per-row ``_sparse_run`` sweeps exactly;
* the warm (``IncrementalSolver``) re-solve path composes with the batched
  kernel and reproduces the sequential warm re-solve;
* re-solving with a different request count only recompiles the kernel
  when the padded row count crosses a power-of-two bucket boundary.
"""

import numpy as np
import pytest

from repro.core import (IncrementalSolver, Problem, SnapshotView, batch_dp,
                        get_planner, lenet_profile, rate_matrix, solve_ould)
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.ould import (_sparse_consts, _sparse_run, _sparse_select,
                             _sparse_select_batch)
from repro.core.profiles import LayerProfile, ModelProfile

MB = 1e6


def _swarm(n=50, requests=16, seed=0, area=300.0, mem_mb=512.0,
           comp=95e9, hotspots=5):
    mob = RPGMobility(RPGParams(n_uavs=n, area_m=area, homogeneous=True),
                      seed=seed)
    rates = rate_matrix(mob.positions(1, seed=seed)[0])
    rng = np.random.default_rng(seed)
    src = rng.integers(0, min(hotspots, n), requests).astype(np.int64)
    return Problem(lenet_profile(), np.full(n, mem_mb * MB),
                   np.full(n, comp), rates, src, np.full(n, 9.5e9))


def _tight(n=12, requests=12, seed=0, mem_cap=30.0):
    """Toy instance with real contention: repairs, spreads and rejections."""
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, 1.0, [8.0, 4.0, 2.0, 1.0][j])
        for j in range(4)), input_bytes=16.0)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 120, (n, 3))
    pos[:, 2] = 50.0
    src = rng.integers(0, n, requests).astype(np.int64)
    return Problem(prof, np.full(n, mem_cap), np.full(n, 40.0),
                   rate_matrix(pos), src)


def _both(prob, **kw):
    seq = solve_ould(prob, solver="dp-sparse", **kw)
    bat = solve_ould(prob, solver="dp-sparse", batch_solve=True, **kw)
    return seq, bat


def _assert_identical(seq, bat):
    np.testing.assert_array_equal(bat.admitted, seq.admitted)
    np.testing.assert_array_equal(bat.assign, seq.assign)
    assert bat.objective == seq.objective       # bitwise, not approx


# ---------------------------------------------------------------------------
# equivalence matrix: sequential loop vs batched epoch solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n", [8, 50, 256])
def test_batched_equals_sequential_matrix(seed, n):
    """Fixed seeds × sizes at the default k: admission, assignment and
    objective are bit-identical, and the fast path actually engages."""
    prob = _swarm(n=n, requests=max(8, n // 4), seed=seed, hotspots=5)
    seq, bat = _both(prob)
    _assert_identical(seq, bat)
    assert bat.dp_stats.n_batched > 0
    assert seq.dp_stats.n_batched == 0          # counter is batched-only


def test_ladder_fallback_parity_under_contention():
    """Contended toy instance at tiny k: the batched pass rejects every
    request (joint-capacity repairs, k-escalations, dense fallback), the
    sequential ladder takes over — and the solve is still bit-identical."""
    for seed in range(3):
        prob = _tight(seed=seed, mem_cap=30.0)
        seq, bat = _both(prob, sparse_k=2, max_path_cost=1e6)
        _assert_identical(seq, bat)
        assert bat.dp_stats.n_batched == 0       # everything fell off
        assert bat.dp_stats.n_dense_fallback > 0


def test_mixed_batched_and_ladder_requests():
    """Mid-contention: some requests commit through the certified batch
    fast path, the rest fall to the ladder, within one solve."""
    prob = _tight(mem_cap=60.0)
    seq, bat = _both(prob, sparse_k=2, max_path_cost=1e6)
    _assert_identical(seq, bat)
    assert 0 < bat.dp_stats.n_batched < prob.n_requests


def test_planner_threads_batch_solve():
    prob = _swarm(n=50, requests=16)
    view = SnapshotView(prob.rates)
    seq = get_planner("ould-dp-sparse").plan(prob, view)
    bat = get_planner("ould-dp-sparse", batch_solve=True).plan(prob, view)
    np.testing.assert_array_equal(bat.admitted, seq.admitted)
    np.testing.assert_array_equal(bat.assign, seq.assign)
    assert bat.objective == seq.objective
    assert bat.solve_stats.n_batched > 0


# ---------------------------------------------------------------------------
# component parity (white-box)
# ---------------------------------------------------------------------------

def _kernel_inputs(prob):
    spb = prob.transfer_cost()
    prof = prob.profile
    consts = _sparse_consts(spb, prof.output_vector(),
                            prof.memory_vector(), prof.compute_vector())
    mem_left = prob.mem_cap.astype(float).copy()
    comp_left = prob.comp_cap.astype(float).copy()
    head = (mem_left / max(float(mem_left.max()), 1e-30)
            + comp_left / max(float(comp_left.max()), 1e-30))
    return spb, consts, mem_left, comp_left, head


@pytest.mark.parametrize("seed", range(3))
def test_select_batch_matches_scalar(seed):
    prob = _swarm(n=40, requests=12, seed=seed)
    spb, consts, mem_left, comp_left, head = _kernel_inputs(prob)
    srcs = np.unique(prob.sources)
    for k in (3, 6, 40):
        cand_b, valid_b = _sparse_select_batch(spb, srcs, mem_left,
                                               comp_left, head, consts, k)
        for q, src in enumerate(srcs):
            cand, valid = _sparse_select(spb, int(src), mem_left,
                                         comp_left, head, consts, k)
            np.testing.assert_array_equal(cand_b[q], cand)
            np.testing.assert_array_equal(valid_b[q], valid)


@pytest.mark.parametrize("seed", range(3))
def test_solve_batch_matches_sparse_run_rows(seed):
    """The jitted sweep vs the numpy reference on identical candidate
    arrays: per-row paths equal, costs bitwise equal (f64 + same op order
    + first-min argmin — DESIGN §8's bit-identity contract)."""
    prob = _swarm(n=40, requests=12, seed=seed)
    spb, consts, mem_left, comp_left, head = _kernel_inputs(prob)
    prof = prob.profile
    Ks = prof.input_bytes
    srcs = np.unique(prob.sources)
    cand, valid = _sparse_select_batch(spb, srcs, mem_left, comp_left,
                                       head, consts, 6)
    paths, costs = batch_dp.solve_batch(spb, Ks, None, srcs, cand, valid,
                                        consts)
    for q, src in enumerate(srcs):
        ref_path, ref_cost = _sparse_run(spb, Ks, int(src), None, cand[q],
                                         valid[q], consts)
        if ref_path is None:
            assert paths[q] is None and costs[q] == np.inf
        else:
            np.testing.assert_array_equal(paths[q], ref_path)
            assert float(costs[q]) == ref_cost


# ---------------------------------------------------------------------------
# warm (IncrementalSolver) path
# ---------------------------------------------------------------------------

def test_warm_batched_resolve_matches_sequential_warm():
    """Epoch re-solves under drift — the tentpole's serving shape: the
    batched warm re-solve equals the sequential warm re-solve exactly."""
    prob = _swarm(n=40, requests=16, seed=2, hotspots=3)
    mob = RPGMobility(RPGParams(n_uavs=40, area_m=300.0, homogeneous=True),
                      seed=2)
    pos = mob.positions(40, seed=5)

    def solver(batch):
        # rel_change=0: any link drift re-places its requests, so every
        # epoch actually exercises the batched re-solve loop.
        s = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                              prob.compute_speed, solver="dp-sparse",
                              rel_change=0.0, batch_solve=batch)
        s.solve(prob.rates, prob.sources)
        return s

    seq, bat = solver(False), solver(True)
    batched = replaced = 0
    for t in (10, 25, 39):
        drift = rate_matrix(pos[t])
        ws, _ = seq.resolve(drift, prob.sources)
        wb, stats = bat.resolve(drift, prob.sources)
        np.testing.assert_array_equal(wb.admitted, ws.admitted)
        np.testing.assert_array_equal(wb.assign, ws.assign)
        assert wb.objective == ws.objective
        batched += stats.n_batched
        replaced += stats.n_replaced
    assert replaced > 0          # the drift actually re-placed requests
    assert batched > 0           # ... and they went through the batch path


# ---------------------------------------------------------------------------
# padding / bucketing contract
# ---------------------------------------------------------------------------

def test_bucket_rows():
    assert batch_dp.bucket_rows(1) == batch_dp.MIN_BUCKET
    assert batch_dp.bucket_rows(8) == 8
    assert batch_dp.bucket_rows(9) == 16
    assert batch_dp.bucket_rows(16) == 16
    assert batch_dp.bucket_rows(1000) == 1024


def test_recompile_only_on_bucket_crossing():
    """Different request counts inside one padded bucket reuse the compiled
    executable; crossing a power-of-two boundary compiles exactly once."""
    prob = _swarm(n=30, requests=8, seed=0)
    spb, consts, mem_left, comp_left, head = _kernel_inputs(prob)
    Ks = prob.profile.input_bytes

    def solve(n_rows):
        srcs = np.arange(n_rows, dtype=np.int64) % 30
        cand, valid = _sparse_select_batch(spb, srcs, mem_left, comp_left,
                                           head, consts, 5)
        batch_dp.solve_batch(spb, Ks, None, srcs, cand, valid, consts)

    solve(3)                                     # bucket 8 (pads up)
    base = batch_dp.compile_count()
    assert base >= 1
    solve(5)                                     # still bucket 8
    solve(8)                                     # exactly at the boundary
    assert batch_dp.compile_count() == base
    solve(9)                                     # bucket 16: one recompile
    assert batch_dp.compile_count() == base + 1
    solve(16)                                    # same bucket again
    assert batch_dp.compile_count() == base + 1


def test_padded_rows_never_leak():
    """S far from a bucket boundary: padded rows are dropped, real rows
    match the scalar reference (the slice-back is exact)."""
    prob = _swarm(n=30, requests=8, seed=1)
    spb, consts, mem_left, comp_left, head = _kernel_inputs(prob)
    Ks = prob.profile.input_bytes
    srcs = np.array([0, 1, 2], np.int64)        # pads 3 -> 8 rows
    cand, valid = _sparse_select_batch(spb, srcs, mem_left, comp_left,
                                       head, consts, 4)
    paths, costs = batch_dp.solve_batch(spb, Ks, None, srcs, cand, valid,
                                        consts)
    assert len(paths) == 3 and costs.shape == (3,)
    for q, src in enumerate(srcs):
        ref_path, ref_cost = _sparse_run(spb, Ks, int(src), None, cand[q],
                                         valid[q], consts)
        np.testing.assert_array_equal(paths[q], ref_path)
        assert float(costs[q]) == ref_cost
