"""shard_map GPipe pipeline vs sequential reference (4-device subprocess)."""

import subprocess
import sys

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.pipeline import pipeline_forward

mesh = Mesh(np.array(jax.devices()).reshape(4), ("stage",))
L, B, D = 8, 8, 16
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)

def block_fn(w_l, x):
    return jnp.tanh(x @ w_l)

x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
ref = x
for l in range(L):
    ref = block_fn(w[l], ref)
out = jax.jit(lambda w, x: pipeline_forward(block_fn, w, x, mesh=mesh,
                                            n_micro=4))(w, x)
err = np.abs(np.asarray(out) - np.asarray(ref)).max()
assert err < 1e-5, err
# also lower+compile with 2 microbatches per stage count variation
out2 = jax.jit(lambda w, x: pipeline_forward(block_fn, w, x, mesh=mesh,
                                             n_micro=8))(w, x)
err2 = np.abs(np.asarray(out2) - np.asarray(ref)).max()
assert err2 < 1e-5, err2
print("OK")
"""


def test_pipeline_matches_sequential():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, (out.stderr[-2000:], out.stdout[-500:])
    assert "OK" in out.stdout
