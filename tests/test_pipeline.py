"""shard_map GPipe pipeline vs sequential reference (4-device subprocess):
uniform cuts (historical contract) plus OULD-style non-uniform stage cuts
with fill/drain bubble coverage (n_micro below/equal/above n_stages)."""

import subprocess
import sys

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.parallel.pipeline import pipeline_forward, pipeline_forward_stages

mesh = Mesh(np.array(jax.devices()).reshape(4), ("stage",))
L, B, D = 8, 8, 16
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)

def block_fn(w_l, x):
    return jnp.tanh(x @ w_l)

x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
ref = x
for l in range(L):
    ref = block_fn(w[l], ref)
out = jax.jit(lambda w, x: pipeline_forward(block_fn, w, x, mesh=mesh,
                                            n_micro=4))(w, x)
err = np.abs(np.asarray(out) - np.asarray(ref)).max()
assert err < 1e-5, err
# also lower+compile with 2 microbatches per stage count variation
out2 = jax.jit(lambda w, x: pipeline_forward(block_fn, w, x, mesh=mesh,
                                             n_micro=8))(w, x)
err2 = np.abs(np.asarray(out2) - np.asarray(ref)).max()
assert err2 < 1e-5, err2

# Non-uniform OULD-style cuts: padded per-stage slices + validity mask.
# n_micro below / equal to / above n_stages so fill/drain bubbles (ticks
# where a stage runs on garbage and the mask discards it) are exercised at
# every occupancy; each pairing compiles once (CPU shard_map compiles are
# expensive, so the matrix is a diagonal, not a product).
for sizes, n_micro in (([1, 3, 2, 2], 2), ([4, 2, 1, 1], 4),
                       ([1, 1, 1, 5], 8)):
    out3 = jax.jit(lambda w, x, s=tuple(sizes), m=n_micro:
                   pipeline_forward_stages(block_fn, w, x, mesh=mesh,
                                           stage_sizes=s, n_micro=m))(w, x)
    err3 = np.abs(np.asarray(out3) - np.asarray(ref)).max()
    assert err3 < 1e-5, (sizes, n_micro, err3)

# Degenerate but legal: one stage hosts a single layer, batch of one
# microbatch (pure fill/drain, no steady state).
out4 = jax.jit(lambda w, x: pipeline_forward_stages(
    block_fn, w, x, mesh=mesh, stage_sizes=[1, 5, 1, 1], n_micro=1))(w, x)
err4 = np.abs(np.asarray(out4) - np.asarray(ref)).max()
assert err4 < 1e-5, err4

# Bad cuts must be rejected, not silently truncated.
for bad in ([2, 2, 2], [3, 3, 1, 0], [4, 4, 4, 4]):
    try:
        pipeline_forward_stages(block_fn, w, x, mesh=mesh, stage_sizes=bad)
    except ValueError:
        pass
    else:
        raise AssertionError(f"stage_sizes {bad} accepted")
print("OK")
"""


def test_pipeline_matches_sequential():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, (out.stderr[-2000:], out.stdout[-500:])
    assert "OK" in out.stdout
