"""Byte-moving transport backends (repro.transport): protocol round trips,
engine equivalence across backends, persistent-compile-cache warm starts,
and the bandwidth-calibrated re-solve loop."""

import os

import jax
import numpy as np
import pytest

from repro.core import Problem, SnapshotView, Solution, get_planner, lenet_profile
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.planner import Plan
from repro.core.radio import RadioParams, rate_matrix
from repro.exec import (ExecutionEngine, calibrated_problem, compile_cache,
                        compile_plan, layer_fns_for, link_payload_bytes,
                        measure_warm_start, stage_signature)
from repro.transport import (InProcTransport, LoopbackTransport,
                             MultiProcTransport, Transport, make_transport)

MB = 1e6
TOL = 1e-5
FRAME_HW = (326, 595, 3)      # lenet layer fns are input-shape-specific


def _uniform_problem(n_nodes=6, requests=2, seed=0, mem_mb=4096):
    mob = RPGMobility(RPGParams(n_uavs=n_nodes, area_m=120.0,
                                homogeneous=False), seed=seed)
    rates = rate_matrix(mob.positions(1, seed=seed)[0], RadioParams())
    sources = np.zeros(requests, np.int64)
    return Problem(lenet_profile(), np.full(n_nodes, mem_mb * MB),
                   np.full(n_nodes, 1e18), rates, sources,
                   compute_speed=np.full(n_nodes, 9.5e9))


def _manual_plan(prob, sizes_per_request):
    M = prob.n_layers
    R = len(sizes_per_request)
    assign = np.zeros((R, M), np.int64)
    for r, sizes in enumerate(sizes_per_request):
        assert sum(sizes) == M
        j = 0
        for node, size in enumerate(sizes):
            assign[r, j:j + size] = node
            j += size
    sol = Solution(assign, 0.0, "feasible", 0.0, np.ones(R, bool),
                   solver="manual")
    return Plan(sol, "manual", "snapshot", prob)


def _frames(rng, n):
    return rng.standard_normal((n, *FRAME_HW)).astype(np.float32)


# ---------------------------------------------------------------------------
# worker protocol and backend registry
# ---------------------------------------------------------------------------

def test_loopback_workers_are_real_processes():
    """Shipments echo exactly through >= 2 distinct worker OS processes."""
    rng = np.random.default_rng(0)
    with LoopbackTransport(n_workers=2) as tp:
        assert len(set(tp.worker_pids)) == 2
        assert os.getpid() not in tp.worker_pids
        for shape, dtype in (((7, 5), np.float32), ((64, 64, 3), np.float32),
                             ((11,), np.int64), ((3, 2), np.float64)):
            arr = (rng.standard_normal(shape) * 10).astype(dtype)
            res = tp.ship(0, 1, arr)
            assert res.moved
            assert res.nbytes == arr.nbytes
            got = np.asarray(res.array)
            assert got.dtype == arr.dtype and got.shape == arr.shape
            np.testing.assert_array_equal(got, arr)
        assert tp.moved_bytes > 0
        ls = tp.link_stats[(0, 1)]
        assert ls.n == 4 and ls.wall_s > 0 and ls.bytes_per_s > 0
    assert not tp.started         # context exit shut the workers down


def test_loopback_worker_ownership():
    tp = LoopbackTransport(n_workers=2, node_of={0: 0, 1: 0, 2: 1})
    assert tp.worker_of(0) == tp.worker_of(1) == 0
    assert tp.worker_of(2) == 1
    assert tp.worker_of(5) == 1   # unmapped nodes fall back to round-robin
    with pytest.raises(ValueError, match="at least one"):
        LoopbackTransport(n_workers=0)


def test_make_transport_registry():
    assert isinstance(make_transport("inproc"), InProcTransport)
    assert isinstance(make_transport("loopback"), LoopbackTransport)
    mp = make_transport("multiproc", group_of=np.array([0, 0, 1, 1]))
    assert isinstance(mp, MultiProcTransport)
    assert mp.n_workers == 2 and mp.worker_of(1) == 0 and mp.worker_of(3) == 1
    for name in ("inproc", "loopback", "multiproc"):
        assert isinstance(make_transport(name), Transport)
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("carrier-pigeon")


def test_multiproc_ships_through_jax_workers():
    """--jax workers land the buffer on their device before echoing."""
    rng = np.random.default_rng(1)
    with MultiProcTransport(group_of=np.array([0, 0, 1, 1])) as tp:
        tp.start()
        assert len(set(tp.worker_pids)) == 2
        assert all(b for b in tp.worker_backends)   # real JAX backends
        arr = rng.standard_normal((128, 64)).astype(np.float32)
        res = tp.ship(0, 3, arr)
        assert res.moved
        np.testing.assert_array_equal(np.asarray(res.array), arr)


# ---------------------------------------------------------------------------
# engine equivalence across backends
# ---------------------------------------------------------------------------

def test_inproc_is_bit_compatible_default():
    """The default backend reproduces the pre-transport path: the shipped
    array IS the consuming stage's input, nothing leaves the process."""
    engine = ExecutionEngine(layer_fns_for(lenet_profile()))
    assert isinstance(engine.transport, InProcTransport)
    tp = InProcTransport()
    arr = np.ones((4, 4), np.float32)
    res = tp.ship(0, 1, arr)
    assert res.array is arr and not res.moved
    assert tp.moved_bytes == 0
    assert res.wall_s >= 0 and tp.link_stats[(0, 1)].nbytes == arr.nbytes


def test_loopback_engine_outputs_bitwise_equal_to_inproc():
    """The tentpole's exactness gate: routing every transfer through worker
    OS processes changes only the timings, never a single output bit."""
    prob = _uniform_problem(requests=2)
    plan = _manual_plan(prob, [[3, 4], [1, 4, 2]])
    graph = compile_plan(plan)
    assert graph.transfers, "plan must have cut points to exercise shipping"
    fns = layer_fns_for(lenet_profile(), key=jax.random.PRNGKey(1))
    frames = _frames(np.random.default_rng(0), 2)

    ref = ExecutionEngine(fns).run(graph, frames)
    with LoopbackTransport(n_workers=2) as tp:
        report = ExecutionEngine(fns, transport=tp).run(graph, frames)
        assert len(set(tp.worker_pids)) == 2
        assert os.getpid() not in tp.worker_pids
        assert tp.moved_bytes > 0

    assert ref.transport == "inproc" and report.transport == "loopback"
    for r in graph.requests:
        assert np.array_equal(report.outputs[r], ref.outputs[r]), r
    # modeled comm decomposition is backend-independent ...
    np.testing.assert_allclose(report.comm_s, ref.comm_s, rtol=0, atol=0)
    # ... while the measured hop walls come from the actual byte movement
    assert all(tr.serialize_s > 0 for tr in report.transfers)
    assert len(report.transfers) == len(graph.transfers)


def test_transport_samples_cover_graph_links():
    """Every link the graph ships on shows up in the transport's realized
    bandwidth ledger — the coverage contract calibrate_rates relies on."""
    prob = _uniform_problem(requests=2)
    plan = _manual_plan(prob, [[3, 4], [2, 2, 1, 2]])
    graph = compile_plan(plan)
    payload = link_payload_bytes(graph)
    fns = layer_fns_for(lenet_profile(), key=jax.random.PRNGKey(2))
    with LoopbackTransport(n_workers=2) as tp:
        ExecutionEngine(fns, transport=tp).run(
            graph, _frames(np.random.default_rng(1), 2))
        assert set(tp.link_stats) == set(payload)
        for link, nbytes in payload.items():
            assert tp.link_stats[link].nbytes == pytest.approx(nbytes)
        spb = tp.measured_spb(prob.n_nodes)
        for s, d in payload:
            assert np.isfinite(spb[s, d]) and spb[s, d] > 0


# ---------------------------------------------------------------------------
# persistent compile cache
# ---------------------------------------------------------------------------

def test_warm_start_cache_hit_faster_than_cold(tmp_path):
    """Recompiling after a simulated process restart lands on the disk
    cache and is measurably faster than the cold compile."""
    fns = layer_fns_for(lenet_profile(), key=jax.random.PRNGKey(3))
    frame = _frames(np.random.default_rng(2), 1)[0]
    rep = measure_warm_start(fns, [(0, 3), (3, 7)], frame,
                             cache_dir=tmp_path / "cc")
    assert (tmp_path / "cc").is_dir()
    assert any((tmp_path / "cc").iterdir()), "nothing persisted to the cache"
    assert rep.warm_total_s < rep.cold_total_s
    assert rep.speedup > 1.2, rep.summary()
    assert len(rep.cold_s) == len(rep.warm_s) == 2


def test_warm_start_rejects_unchained_ranges(tmp_path):
    fns = layer_fns_for(lenet_profile())
    frame = np.zeros(FRAME_HW, np.float32)
    with pytest.raises(ValueError, match="chain from layer 0"):
        measure_warm_start(fns, [(2, 5)], frame, cache_dir=tmp_path)


def test_compile_cache_enable_restores(tmp_path):
    prev = compile_cache.cache_dir()
    try:
        d = compile_cache.enable(tmp_path / "cc2")
        assert compile_cache.is_enabled() and compile_cache.cache_dir() == d
    finally:
        if prev is None:
            compile_cache.disable()
        else:
            compile_cache.enable(prev)
    assert compile_cache.cache_dir() == prev


def test_engine_warm_start_compiles_signature():
    """warm_start pre-compiles a stage signature (the churn-rejoin path)."""
    prob = _uniform_problem(requests=1)
    graph = compile_plan(_manual_plan(prob, [[1, 4, 2]]))
    sig = stage_signature(graph)
    engine = ExecutionEngine(layer_fns_for(lenet_profile(),
                                           key=jax.random.PRNGKey(4)))
    wall = engine.warm_start(sig, np.zeros(FRAME_HW, np.float32))
    assert wall > 0
    for s, e in sig:
        assert (s, e) in engine._closures


# ---------------------------------------------------------------------------
# bandwidth-calibrated re-solves
# ---------------------------------------------------------------------------

def test_comm_calibration_closes_the_loop():
    """Realized per-link bandwidth from a loopback run replaces the analytic
    rates, the provenance rides into the re-solved Plan.problem, and the
    modeled-vs-realized comm gap collapses on the re-run."""
    mob = RPGMobility(RPGParams(n_uavs=8, area_m=150.0, homogeneous=False),
                      seed=0)
    rates = rate_matrix(mob.positions(1)[0], RadioParams())
    rng = np.random.default_rng(0)
    sources = rng.integers(0, 3, 4).astype(np.int64)
    prob = Problem(lenet_profile(), np.full(8, 128 * MB), np.full(8, 95e9),
                   rates, sources, compute_speed=np.full(8, 9.5e9))
    assert prob.comm_source == "analytic"
    fns = layer_fns_for(lenet_profile(), key=jax.random.PRNGKey(0))
    frames = _frames(rng, 4)
    planner = get_planner("ould-dp")

    with LoopbackTransport(n_workers=2) as tp:
        engine = ExecutionEngine(fns, transport=tp)
        plan = planner.plan(prob, SnapshotView(rates))
        graph = compile_plan(plan)
        assert graph.transfers, "scenario must ship bytes to calibrate comm"
        report = engine.run(
            graph, frames, predicted_s=np.asarray(plan.evaluate().per_request_s))

        cal_prob, recon = calibrated_problem(prob, report, transport=tp)
        assert recon.transport == "loopback"
        assert recon.link_measured_spb and recon.comm_mae_s > 0
        assert "comm[loopback]" in recon.summary()
        assert cal_prob.comm_source == "measured:loopback"
        # sampled links carry realized rates, unsampled keep analytic ones
        for (s, d), spb in recon.link_measured_spb.items():
            assert cal_prob.transfer_cost()[s, d] == pytest.approx(spb)
        untouched = [(s, d) for s in range(8) for d in range(8) if s != d
                     and (s, d) not in recon.link_measured_spb]
        sd = untouched[0]
        assert cal_prob.rates[sd] == pytest.approx(rates[sd])

        replan = planner.plan(cal_prob, SnapshotView(cal_prob.rates))
        assert replan.problem.comm_source == "measured:loopback"
        rereport = engine.run(
            regraph := compile_plan(replan), frames,
            predicted_s=np.asarray(replan.evaluate().per_request_s))
        _, recon2 = calibrated_problem(cal_prob, rereport, transport=tp)
        assert regraph.requests
        # analytic radio delays are orders of magnitude off localhost
        # sockets; after substitution the modeled delays track realized
        assert recon2.comm_mae_s < recon.comm_mae_s


def test_calibrate_rates_ignores_bogus_samples():
    from repro.exec import calibrate_rates
    prob = _uniform_problem(requests=1)
    cal = calibrate_rates(prob, {(0, 0): 1e-9, (0, 1): np.nan,
                                 (1, 2): -1.0, (99, 0): 1e-9},
                          source="measured:test")
    np.testing.assert_array_equal(cal.rates, prob.rates)
    assert cal.comm_source == "measured:test"
    assert prob.comm_source == "analytic"      # never mutated in place
