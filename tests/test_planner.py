"""Planner protocol / TopologyView / registry: every registered strategy
honors the Solution contract through Plan, and the new API is bit-identical
to the legacy entry points on fixed-seed instances."""

import dataclasses

import numpy as np
import pytest

from repro.core import (HorizonView, IncrementalSolver, Plan, Problem,
                        RPGMobility, RPGParams, SnapshotView, available_planners,
                        evaluate, get_planner, lenet_profile, make_view,
                        rate_matrix, register_planner, solve_heuristic,
                        solve_ould, to_stages)
from repro.core.profiles import LayerProfile, ModelProfile

MB = 1e6

REGISTERED = ("ould-ilp", "ould-dp", "ould-mp", "nearest", "hrm",
              "nearest-hrm", "incremental")


def _swarm(seed=0, n=8, requests=4, steps=4):
    mob = RPGMobility(RPGParams(n_uavs=n, area_m=150.0, homogeneous=False),
                      seed=seed)
    rates = rate_matrix(mob.positions(1, seed=seed)[0])
    horizon = mob.predicted_rates(steps, seed=seed + 1)
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 3, requests).astype(np.int64)
    prob = Problem(lenet_profile(), np.full(n, 192 * MB), np.full(n, 95e9),
                   rates, src, np.full(n, 9.5e9))
    return prob, rates, horizon


def _toy_problem(n=3, r=2, mem_cap=30.0, seed=0):
    prof = ModelProfile("toy", tuple(
        LayerProfile(f"l{j}", 10.0, 1.0, [8.0, 4.0, 2.0, 1.0][j])
        for j in range(4)), input_bytes=16.0)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 80, (n, 3))
    pos[:, 2] = 50.0
    return Problem(prof, np.full(n, mem_cap), np.full(n, 1e9),
                   rate_matrix(pos), np.arange(r) % n)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_resolves_all_seven_strategies():
    for name in REGISTERED:
        planner = get_planner(name)
        assert planner.name == name
        assert planner.view_kinds
    assert set(REGISTERED) <= set(available_planners())


def test_unknown_planner_raises_with_catalog():
    with pytest.raises(KeyError, match="available"):
        get_planner("llhr")


def test_get_planner_returns_fresh_instances():
    assert get_planner("incremental") is not get_planner("incremental")


def test_register_planner_plugin_roundtrip():
    @register_planner("test-constant")
    class _Const:
        name = "test-constant"
        view_kinds = ("snapshot",)

        def plan(self, problem, view, *, request_ids=None):
            sol = solve_ould(view.bind(problem), solver="dp")
            return Plan(sol, self.name, view.kind, view.bind(problem))

    try:
        prob, rates, _ = _swarm()
        plan = get_planner("test-constant").plan(prob, SnapshotView(rates))
        assert plan.planner_name == "test-constant"
    finally:
        from repro.core.planner import _REGISTRY
        _REGISTRY.pop("test-constant")


# ---------------------------------------------------------------------------
# TopologyView
# ---------------------------------------------------------------------------

def test_view_rank_validation_and_inference():
    prob, rates, horizon = _swarm()
    with pytest.raises(ValueError):
        SnapshotView(horizon)
    with pytest.raises(ValueError):
        HorizonView(rates)
    assert make_view(rates).kind == "snapshot"
    assert make_view(horizon).kind == "horizon"
    assert make_view(horizon).snapshot().kind == "snapshot"


def test_snapshot_planners_reject_horizon_views():
    prob, rates, horizon = _swarm()
    for name in ("nearest", "hrm", "nearest-hrm", "ould-ilp", "ould-dp"):
        with pytest.raises(ValueError, match="views"):
            get_planner(name).plan(prob, HorizonView(horizon))
    with pytest.raises(ValueError, match="views"):
        get_planner("ould-mp").plan(prob, SnapshotView(rates))


def test_view_bind_masks_dead_nodes_everywhere():
    prob, rates, _ = _swarm()
    alive = np.ones(prob.n_nodes, bool)
    alive[5] = False
    bound = SnapshotView(rates, alive).bind(prob)
    assert bound.mem_cap[5] == 0.0 and bound.comp_cap[5] == 0.0
    assert (bound.rates[5, :] == 0).all() and (bound.rates[:, 5] == 0).all()
    # all-alive: no copy, caps untouched
    bound2 = SnapshotView(rates).bind(prob)
    assert bound2.rates is rates
    np.testing.assert_array_equal(bound2.mem_cap, prob.mem_cap)


# ---------------------------------------------------------------------------
# equivalence with legacy entry points (fixed seeds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,legacy", [
    ("ould-ilp", lambda p: solve_ould(p)),
    ("ould-dp", lambda p: solve_ould(p, solver="dp")),
    ("nearest", lambda p: solve_heuristic(p, "nearest")),
    ("hrm", lambda p: solve_heuristic(p, "hrm")),
    ("nearest-hrm", lambda p: solve_heuristic(p, "nearest_hrm")),
])
def test_snapshot_planners_bit_identical_to_legacy(name, legacy):
    for seed in (0, 1):
        prob, rates, _ = _swarm(seed=seed)
        plan = get_planner(name).plan(prob, SnapshotView(rates))
        sol = legacy(prob)
        np.testing.assert_array_equal(plan.assign, sol.assign)
        np.testing.assert_array_equal(plan.admitted, sol.admitted)
        assert plan.objective == sol.objective
        assert plan.status == sol.status


def test_ould_mp_planner_bit_identical_to_legacy():
    prob, _, horizon = _swarm()
    hp = dataclasses.replace(prob, rates=horizon)
    for solver in ("ilp", "dp"):
        plan = get_planner("ould-mp", solver=solver).plan(
            hp, HorizonView(horizon))
        sol = solve_ould(hp, solver=solver)
        np.testing.assert_array_equal(plan.assign, sol.assign)
        assert plan.objective == sol.objective


def test_incremental_planner_bit_identical_to_incremental_solver():
    prob, rates, _ = _swarm()
    mob = RPGMobility(RPGParams(n_uavs=8, area_m=150.0, homogeneous=False),
                      seed=0)
    drifted = rate_matrix(mob.positions(30, seed=3)[29])
    planner = get_planner("incremental")
    inc = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                            prob.compute_speed, solver="dp")
    p1 = planner.plan(prob, SnapshotView(rates))          # cold prime
    s1, _ = inc.resolve(rates, prob.sources)
    p2 = planner.plan(prob, SnapshotView(drifted))        # warm re-solve
    s2, st2 = inc.resolve(drifted, prob.sources)
    for plan, sol in ((p1, s1), (p2, s2)):
        np.testing.assert_array_equal(plan.assign, sol.assign)
        assert plan.objective == sol.objective
    assert p2.warm and not p1.warm
    assert p2.solve_stats.n_kept == st2.n_kept
    assert p2.solve_stats.n_repriced == st2.n_repriced


def test_incremental_planner_cold_mode_matches_solve():
    prob, rates, _ = _swarm()
    cold_planner = get_planner("incremental", warm=False)
    p1 = cold_planner.plan(prob, SnapshotView(rates))
    p2 = cold_planner.plan(prob, SnapshotView(rates))
    assert not p1.warm and not p2.warm
    assert p2.solve_stats.cold


# ---------------------------------------------------------------------------
# Plan honors the Solution contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["ould-ilp", "ould-dp", "nearest", "hrm",
                                  "nearest-hrm", "incremental"])
def test_plan_contract_shape_sentinel_and_evaluate(name):
    # 2 requests × 40B > 3 nodes × 20B ⇒ rejection guaranteed
    prob = _toy_problem(mem_cap=20.0)
    plan = get_planner(name).plan(prob, SnapshotView(prob.rates))
    assert plan.assign.shape == (prob.n_requests, prob.n_layers)
    assert not plan.admitted.all()
    for r in np.flatnonzero(~plan.admitted):
        assert (plan.assign[r] == -1).all()      # rejection sentinel
        with pytest.raises(ValueError, match="rejected"):
            plan.stages(r)
    ev = plan.evaluate()
    assert ev.feasible
    assert ev.n_admitted == plan.n_admitted


def test_plan_stages_bridge_matches_to_stages():
    prob, rates, _ = _swarm()
    plan = get_planner("ould-dp").plan(prob, SnapshotView(rates))
    for r in np.flatnonzero(plan.admitted):
        assert plan.stages(r) == to_stages(plan.assign[r])


def test_evaluate_guard_still_rejects_sentinel_marked_admitted():
    from repro.core.ould import Solution
    prob = _toy_problem(mem_cap=20.0)
    bad = Solution(np.full((2, 4), -1, np.int64), 0.0, "feasible", 0.0,
                   np.ones(2, bool))
    with pytest.raises(AssertionError, match="sentinel"):
        evaluate(prob, bad)


# ---------------------------------------------------------------------------
# incremental transfer-cost pricing
# ---------------------------------------------------------------------------

def test_incremental_transfer_cost_bit_identical():
    from repro.core import incremental_transfer_cost, transfer_cost
    rng = np.random.default_rng(0)
    for shape in ((6, 6), (4, 6, 6)):
        ref = rng.uniform(1e6, 1e8, shape)
        new = ref.copy()
        new[..., 2, :] *= 1.5        # node 2's outbound links drift
        ref_spb = transfer_cost(ref)
        spb, repriced = incremental_transfer_cost(new, ref, ref_spb)
        np.testing.assert_array_equal(spb, transfer_cost(new))
        assert repriced[2].sum() == 5 and repriced.sum() == 5  # row 2 \ diag
        # no drift ⇒ nothing re-priced
        spb2, repriced2 = incremental_transfer_cost(ref, ref, ref_spb)
        assert not repriced2.any()
        np.testing.assert_array_equal(spb2, ref_spb)


def test_incremental_transfer_cost_shape_change_full_reprice():
    from repro.core import incremental_transfer_cost, transfer_cost
    rng = np.random.default_rng(1)
    ref = rng.uniform(1e6, 1e8, (5, 5))
    new = rng.uniform(1e6, 1e8, (2, 5, 5))
    spb, repriced = incremental_transfer_cost(new, ref, transfer_cost(ref))
    assert repriced.all()
    np.testing.assert_array_equal(spb, transfer_cost(new))


def test_price_band_coarser_than_placement_band_rejected():
    """Pricing staleness above rel_change would hide drift from the
    re-place trigger — the constructor must refuse it."""
    with pytest.raises(ValueError, match="price_rel_change"):
        IncrementalSolver(lenet_profile(), np.full(3, 1e9), np.full(3, 1e9),
                          rel_change=0.05, price_rel_change=0.2)


def test_plan_evaluate_per_step_matches_manual_loop():
    prob, _, horizon = _swarm()
    hp = dataclasses.replace(prob, rates=horizon)
    plan = get_planner("ould-mp", solver="dp").plan(hp, HorizonView(horizon))
    steps = plan.evaluate_per_step()
    assert len(steps) == horizon.shape[0]
    for t, ev in enumerate(steps):
        manual = evaluate(dataclasses.replace(plan.problem,
                                              rates=horizon[t]),
                          plan.solution)
        assert ev.comm_latency_s == manual.comm_latency_s
    # explicit rates: play a snapshot plan forward over the horizon
    prob0 = dataclasses.replace(prob, rates=horizon[0])
    snap = get_planner("ould-dp").plan(prob0, SnapshotView(horizon[0]))
    assert len(snap.evaluate_per_step(horizon)) == horizon.shape[0]


def test_admission_controller_history_is_lightweight():
    from repro.core import ResolveStats
    from repro.runtime.serve import AdmissionController
    prob, rates, _ = _swarm()
    ctrl = AdmissionController("nearest")
    ctrl.admit(prob, rates)
    ctrl.admit(prob, SnapshotView(rates))
    assert len(ctrl.history) == 2
    assert all(isinstance(s, ResolveStats) for s in ctrl.history)
    assert ctrl.total_solve_time_s >= 0.0


def test_solver_repricing_matches_full_pricing_end_to_end():
    """Warm resolves with row re-pricing must equal a fresh solver that
    prices every epoch from scratch."""
    prob, rates, _ = _swarm(seed=2)
    mob = RPGMobility(RPGParams(n_uavs=8, area_m=150.0, homogeneous=False),
                      seed=2)
    pos = mob.positions(40, seed=5)
    inc = IncrementalSolver(prob.profile, prob.mem_cap, prob.comp_cap,
                            prob.compute_speed, solver="dp")
    inc.solve(rates, prob.sources)
    for t in (10, 20, 39):
        drift = rate_matrix(pos[t])
        warm, stats = inc.resolve(drift, prob.sources)
        cold = solve_ould(dataclasses.replace(prob, rates=drift), solver="dp")
        assert warm.objective == pytest.approx(cold.objective, rel=1e-12)
        assert stats.n_repriced >= 0
