"""repro.obs: flight-recorder tracer, metrics registry, and the end-to-end
per-frame latency-breakdown audit (DESIGN.md §9)."""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core import Problem, Solution, lenet_profile
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.planner import Plan
from repro.core.radio import RadioParams, rate_matrix
from repro.exec import ExecutionEngine, compile_plan, layer_fns_for
from repro.obs import (ADMISSION, FRAMES, NULL_TRACER, QUEUE, SOLVER,
                       Counter, Gauge, Histogram, MetricsRegistry,
                       NullTracer, Tracer)
from repro.runtime.serve import AdmissionController
from repro.runtime.swarm import SwarmScenario, simulate

MB = 1e6

# S6-style sustained overload, trimmed: one group (queue-driven tails),
# admission uncapped, churn on — every terminal frame fate is reachable.
OVERLOAD = SwarmScenario(
    n_groups=1, duration_ticks=100, epoch_ticks=10, arrival_rate_hz=4.5,
    hold_ticks_mean=240.0, mem_mb_hotspot_group=4096.0,
    mem_mb_other_groups=4096.0, comp_cap_flops=1e18, gflops=5e9,
    deadline_s=2.0, mtbf_s=90.0, mttr_s=30.0)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_ring_keeps_latest_and_counts_dropped():
    tr = Tracer(capacity=8)
    for i in range(12):
        tr.span(QUEUE, "s", float(i), 0.5, lane=i, frame=100 + i)
    assert tr.n_events == 8 and tr.n_dropped == 4 and tr.seq == 12
    ev = tr.events()
    np.testing.assert_array_equal(ev["ts"], np.arange(4.0, 12.0))
    np.testing.assert_array_equal(ev["frame"], np.arange(104, 112))
    assert list(ev["name"]) == ["s"] * 8


def test_span_batch_scalar_and_array_operands():
    tr = Tracer(capacity=64)
    ts = np.array([1.0, 2.0, 3.0])
    tr.span_batch(QUEUE, "w", ts, np.array([0.1, 0.2, 0.3]),
                  lane=np.array([5, 6, 7]), frame=np.array([10, 11, 12]),
                  a0=2.5)                       # scalar broadcast: slice fill
    tr.instant_batch(FRAMES, "drop", ts + 9.0, lane=1)
    w = tr.select("w")
    np.testing.assert_allclose(w["dur"], [0.1, 0.2, 0.3])
    np.testing.assert_array_equal(w["lane"], [5, 6, 7])
    np.testing.assert_array_equal(w["frame"], [10, 11, 12])
    np.testing.assert_allclose(w["a0"], 2.5)
    d = tr.select("drop")
    assert (d["dur"] == -1.0).all() and (d["lane"] == 1).all()
    tr.span_batch(QUEUE, "w", np.zeros(0), 0.0)   # empty append is a no-op
    assert tr.n_events == 6


def test_batch_append_wraps_and_oversize_keeps_newest():
    tr = Tracer(capacity=8)
    tr.span_batch(QUEUE, "a", np.arange(5.0), 0.1)      # fills 0..4
    tr.span_batch(QUEUE, "b", np.arange(5.0) + 10, 0.1)  # wraps
    ev = tr.events()                                     # oldest-first
    np.testing.assert_array_equal(ev["ts"], [2, 3, 4, 10, 11, 12, 13, 14])
    assert tr.n_dropped == 2
    big = Tracer(capacity=4)
    big.span_batch(QUEUE, "c", np.arange(100.0), 0.1)   # n >= capacity
    np.testing.assert_array_equal(big.events()["ts"], [96, 97, 98, 99])
    assert big.n_dropped == 96


def test_intern_and_track_registration():
    tr = Tracer(capacity=8)
    assert tr.intern("solve", "n_admitted", "gated") == tr.intern("solve")
    code = tr.track("my_subsystem")             # new subsystem joins here
    assert code == len(("admission", "solver", "queue", "engine",
                        "transport", "frames"))
    assert tr.track("my_subsystem") == code and tr.track("frames") == FRAMES
    tr.span(code, "tick", 0.0, 1.0)
    assert tr.events()["track"][0] == "my_subsystem"


def test_export_chrome_format(tmp_path):
    tr = Tracer(capacity=16)
    tr.intern("solve", "n_admitted", "queue_gated")
    tr.span(SOLVER, "solve", 1.0, 0.25, a0=3.0, a1=1.0,
            args={"cold_dispatch": True})
    tr.instant(ADMISSION, "admit", 1.5, frame=7)
    path = tmp_path / "t.json"
    n = tr.export_chrome(path)
    doc = json.loads(path.read_text())
    assert doc["otherData"]["n_dropped"] == 0
    evs = doc["traceEvents"]
    assert n == len(evs)
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta if m["name"] == "process_name"} \
        == {"solver", "admission"}
    span = next(e for e in evs if e["ph"] == "X")
    assert span["ts"] == 1.0e6 and span["dur"] == 0.25e6   # microseconds
    assert span["args"] == {"n_admitted": 3.0, "queue_gated": 1.0,
                            "cold_dispatch": True}          # labels + rich
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["args"]["frame"] == 7


def test_null_tracer_is_inert():
    nt = NullTracer()
    assert not nt.enabled and NULL_TRACER.enabled is False
    nt.span(QUEUE, "x", 0.0, 1.0)
    nt.instant(QUEUE, "x", 0.0)
    nt.span_batch(QUEUE, "x", np.arange(3.0), 0.1)
    nt.instant_batch(QUEUE, "x", np.arange(3.0))
    assert nt.n_events == 0 and nt.n_dropped == 0 and nt.now() == 0.0
    assert nt.track("anything") == -1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_instruments_and_snapshot():
    m = MetricsRegistry()
    m.counter("sim.served").inc(3)
    m.counter("sim.served").inc()               # same instrument
    m.gauge("solver.total_solve_s").set(1.25)
    h = m.histogram("sim.latency_s", (0.1, 1.0, 10.0))
    h.observe_many(np.array([0.05, 0.5, 0.5, 2.0, 100.0]))
    h.observe(0.5)
    snap = m.snapshot()
    assert snap["sim.served"] == 4
    assert snap["solver.total_solve_s"] == 1.25
    assert snap["sim.latency_s"]["count"] == 6
    assert snap["sim.latency_s"]["counts"] == [1, 3, 1, 1]
    assert h.quantile(0.5) == 1.0               # bucket upper edge
    assert h.quantile(1.0) == float("inf")      # overflow bucket
    assert h.min == 0.05 and h.max == 100.0
    assert m.names() == sorted(snap)


def test_metrics_kind_conflict_and_histogram_edges():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        m.gauge("x")
    with pytest.raises(ValueError, match="needs edges"):
        m.histogram("h")
    with pytest.raises(ValueError, match="strictly increasing"):
        Histogram((1.0, 1.0))
    c, g = Counter(), Gauge()
    c.inc(2.5)
    g.set(7)
    assert c.value == 2.5 and g.value == 7


# ---------------------------------------------------------------------------
# end-to-end audit (the satellite acceptance test)
# ---------------------------------------------------------------------------

def test_traced_off_path_bit_identical():
    """Default NullTracer run == untraced run == ring-buffer run."""
    scn = dataclasses.replace(OVERLOAD, duration_ticks=40)
    r0 = simulate(scn, "nearest", seed=3)
    r1 = simulate(scn, "nearest", seed=3, tracer=NullTracer())
    r2 = simulate(scn, "nearest", seed=3, tracer=Tracer(1 << 16))
    for r in (r1, r2):
        assert (r.served, r.missed, r.outages, r.dropped,
                r.frames_rejected) == (r0.served, r0.missed, r0.outages,
                                       r0.dropped, r0.frames_rejected)
        np.testing.assert_array_equal(r.latencies, r0.latencies)
    assert r0.metrics["sim.served"] == r0.served     # registry agrees too


@pytest.mark.parametrize("policy,fate", [("edf+drop", "dropped"),
                                         ("fifo+reject", "frames_rejected")])
def test_latency_breakdown_audit(policy, fate):
    """Span algebra ``frame.dur == base + wait + service`` for every
    completion, and event conservation vs SimResult: every served frame
    ends as exactly one of outage / completion span / drop / reject.
    Bottleneck mode — the per-hop twin audits the tandem spans below."""
    scn = dataclasses.replace(OVERLOAD, service_policy=policy,
                              queue_model="bottleneck")
    tr = Tracer(1 << 18)
    r = simulate(scn, "nearest", seed=1, tracer=tr)
    assert getattr(r, fate) > 0 and r.outages > 0    # the fates all occur
    assert tr.n_dropped == 0                         # ring held everything

    f, w, s = tr.select("frame"), tr.select("queue_wait"), tr.select("service")
    # batch appends preserve emission order: the three span families align
    np.testing.assert_array_equal(f["frame"], w["frame"])
    np.testing.assert_array_equal(f["frame"], s["frame"])
    np.testing.assert_allclose(f["dur"], f["a0"] + w["dur"] + s["dur"],
                               atol=1e-9)
    assert f["ts"].size == r.latencies.size
    np.testing.assert_allclose(np.sort(f["dur"]), np.sort(r.latencies))

    n_drop = tr.select("drop")["ts"].size
    n_rej = tr.select("reject_queue")["ts"].size
    n_out = tr.select("outage")["ts"].size
    assert n_out == r.outages and n_drop == r.dropped
    assert n_rej == r.frames_rejected
    assert r.served == n_out + f["ts"].size + n_drop + n_rej

    # the registry snapshot mirrors the same totals
    assert r.metrics["sim.served"] == r.served
    assert r.metrics["queue.dropped"] == r.dropped
    assert r.metrics["sim.latency_s"]["count"] == r.latencies.size


def test_perhop_latency_breakdown_audit():
    """Per-hop event conservation (the tandem-network twin of the audit
    above): every completed frame's duration decomposes into its hop
    spans — ``frame.dur == Σ hop_wait + Σ hop_service + Σ link`` grouped
    per frame id — and the fate counts still conserve vs SimResult."""
    tr = Tracer(1 << 19)
    r = simulate(OVERLOAD, "nearest", seed=1, tracer=tr)
    assert tr.n_dropped == 0
    f = tr.select("frame")
    assert f["ts"].size == r.latencies.size
    np.testing.assert_allclose(np.sort(f["dur"]), np.sort(r.latencies))
    # a0/a1 carry the wait/work split: they must re-sum to the duration
    np.testing.assert_allclose(f["dur"], f["a0"] + f["a1"], atol=1e-9)

    # A stream serves one frame per tick, so frame ids repeat across
    # windows — conservation is audited per *stream*: the summed hop spans
    # of each id must equal its summed frame durations.
    hops: dict[int, float] = {}
    for name in ("hop_wait", "hop_service", "link"):
        ev = tr.select(name)
        assert ev["ts"].size > 0                 # all three families emitted
        for fr, dur in zip(ev["frame"], ev["dur"]):
            hops[int(fr)] = hops.get(int(fr), 0.0) + float(dur)
    frames: dict[int, float] = {}
    for fr, dur in zip(f["frame"], f["dur"]):
        frames[int(fr)] = frames.get(int(fr), 0.0) + float(dur)
    assert set(hops) == set(frames)
    for fr, tot in frames.items():
        assert hops[fr] == pytest.approx(tot, abs=1e-6)

    n_out = tr.select("outage")["ts"].size
    assert n_out == r.outages
    assert r.served == n_out + f["ts"].size + r.dropped + r.frames_rejected


def test_trace_carries_churn_and_epoch_solves(tmp_path):
    scn = dataclasses.replace(OVERLOAD, duration_ticks=60)
    tr = Tracer(1 << 17)
    r = simulate(scn, "incremental", seed=2, tracer=tr)
    solves = tr.select("solve")
    assert solves["ts"].size >= 1                # epoch re-solves traced
    assert (tr.select("node_fail")["ts"].size
            + tr.select("node_rejoin")["ts"].size) > 0
    assert tr.select("arrival")["ts"].size == r.metrics["sim.arrivals"]
    # exported trace is valid Chrome JSON with the churn track registered
    path = tmp_path / "swarm.json"
    tr.export_chrome(path)
    doc = json.loads(path.read_text())
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert {"solver", "queue", "frames", "churn"} <= names


# ---------------------------------------------------------------------------
# solver spans: cold-dispatch flag (the ResolveStats wall-time fix)
# ---------------------------------------------------------------------------

def _pool_problem(n_nodes=23, requests=6, seed=0):
    mob = RPGMobility(RPGParams(n_uavs=n_nodes, area_m=150.0,
                                homogeneous=False), seed=seed)
    rates = rate_matrix(mob.positions(1, seed=seed)[0], RadioParams())
    src = (np.arange(requests) % 3).astype(np.int64)
    return Problem(lenet_profile(), np.full(n_nodes, 4096 * MB),
                   np.full(n_nodes, 1e18), rates, src,
                   compute_speed=np.full(n_nodes, 9.5e9))


def test_cold_dispatch_flag_separates_compile_from_solve():
    """A batched-DP solve that triggered XLA compilation flags its stats;
    the identical re-solve does not — so solve_time_s is only read as
    steady-state cost when cold_dispatch is False."""
    prob = _pool_problem()       # unusual shape ⇒ compiles within this test
    tr = Tracer(1 << 12)
    ctrl = AdmissionController("ould-dp-sparse", tracer=tr, batch_solve=True)
    ids = list(range(prob.n_requests))
    p1 = ctrl.admit(prob, prob.rates, request_ids=ids, now_s=0.0)
    p2 = ctrl.admit(prob, prob.rates, request_ids=ids, now_s=1.0)
    s1, s2 = p1.solve_stats, p2.solve_stats
    assert s1.n_batched > 0
    assert s2.n_jit_compiles == 0 and not s2.cold_dispatch
    assert s1.n_jit_compiles >= s2.n_jit_compiles
    # both rounds traced: solver spans carry the flag in their rich args
    ev = tr.events()
    solver_rich = [tr._rich[k] for k in sorted(tr._rich)
                   if "cold_dispatch" in tr._rich[k]]
    assert len(solver_rich) == 2
    assert solver_rich[1]["cold_dispatch"] is False
    assert ev["name"].tolist().count("solve") == 2
    # per-request admission verdict instants cover the whole batch
    n_adm = tr.select("admit")["ts"].size
    n_rej = tr.select("reject")["ts"].size
    assert n_adm + n_rej == 2 * len(ids)


# ---------------------------------------------------------------------------
# engine + transport spans
# ---------------------------------------------------------------------------

def test_engine_and_transport_spans():
    """One ``stage`` span per launched task (backdated: compile excluded),
    one ``ship`` span per boundary transfer, bytes accounted exactly."""
    profile = lenet_profile()
    prob = _pool_problem(n_nodes=6, requests=2)
    M = prob.n_layers
    assign = np.zeros((2, M), np.int64)
    assign[:, 3:] = 1                            # 2 stages: layers cross a link
    sol = Solution(assign, 0.0, "feasible", 0.0, np.ones(2, bool),
                   solver="manual")
    graph = compile_plan(Plan(sol, "manual", "snapshot", prob))
    tr = Tracer(1 << 12)
    engine = ExecutionEngine(layer_fns_for(profile, key=jax.random.PRNGKey(0)),
                             tracer=tr)
    frames = np.random.default_rng(0).standard_normal(
        (2, 326, 595, 3)).astype(np.float32)
    engine.run(graph, frames)
    stages = tr.select("stage")
    ships = tr.select("ship")
    assert stages["ts"].size == len(graph.tasks)
    assert (stages["dur"] > 0).all() and (stages["ts"] >= 0).all()
    assert ships["ts"].size == len(graph.transfers)
    # a0 = realized bytes per shipment (batched shared stages ship once for
    # all requests, so realized >= the per-request modeled boundary bytes)
    assert ships["a0"].min() > 0
    assert ships["a0"].sum() >= max(t.nbytes for t in graph.transfers)
    ev = tr.events()
    assert set(ev["track"]) == {"engine", "transport"}
