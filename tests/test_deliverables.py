"""Deliverable-locking tests: the dry-run artifact set, config registry
completeness, and roofline-table invariants."""

import json
import pathlib

import pytest

import repro.configs as C
from repro.configs.base import SHAPES

ART = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" / \
    "artifacts" / "dryrun"

EXPECTED_SKIPS = {  # long_500k on pure full-attention archs (DESIGN.md §5)
    "granite_moe_3b", "llama4_maverick_400b", "musicgen_medium",
    "minicpm3_4b", "yi_6b", "internlm2_1p8b", "phi3_vision_4p2b",
}


@pytest.fixture(scope="module", autouse=True)
def _ensure_dryrun_artifacts():
    """On a clean checkout, generate any missing baseline dry-run artifact
    via ``repro.launch.dryrun`` instead of hard-failing.  The committed
    artifact set makes this a no-op in CI; regenerating the full grid from
    scratch compiles every (arch × shape × mesh) cell and takes a while."""
    missing = [(arch, shape, mesh == "multi")
               for arch in C.ARCH_IDS for shape in SHAPES
               for mesh in ("single", "multi")
               if not (ART / f"{arch}__{shape}__{mesh}.json").exists()]
    if missing:
        from repro.launch import dryrun
        for arch, shape, multi in missing:
            dryrun.run_cell(arch, shape, multi, verbose=False)


def test_registry_has_all_ten_archs():
    assert len(C.ARCH_IDS) == 10
    for arch in C.ARCH_IDS:
        cfg = C.get_config(arch)
        assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
        # spec aliases resolve too
        for alias, mod in C.ALIASES.items():
            assert C.get_config(alias).name


def test_shape_suite():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_artifacts_complete(mesh):
    """Every (arch × shape × mesh) cell has a recorded outcome: compiled OK
    or a documented long_500k skip — no errors, no gaps."""
    missing, errors = [], []
    for arch in C.ARCH_IDS:
        for shape in SHAPES:
            p = ART / f"{arch}__{shape}__{mesh}.json"
            if not p.exists():
                missing.append(p.name)
                continue
            rec = json.loads(p.read_text())
            if rec["status"] == "error":
                errors.append(p.name)
            elif rec["status"] == "skipped":
                assert shape == "long_500k" and arch in EXPECTED_SKIPS, p.name
            else:
                assert rec["status"] == "ok"
                assert rec["compile_s"] > 0
                assert rec["memory"]["peak_memory_in_bytes"] > 0
    assert not missing, missing
    assert not errors, errors


def test_dryrun_costs_positive_and_probed():
    for arch in C.ARCH_IDS:
        rec = json.loads((ART / f"{arch}__train_4k__single.json").read_text())
        assert rec["status"] == "ok"
        # probe-derived totals exist and exceed the loop-body-once raw count
        assert rec["derived_flops_per_partition"] > 0
        assert (rec["derived_flops_per_partition"]
                >= rec["flops_per_partition"] * 0.9)


def test_optimized_sweep_never_regresses_dominant_term():
    """§Perf contract: after gating, no cell's optimized dominant roofline
    term exceeds its paper-faithful baseline by more than noise."""
    for arch in C.ARCH_IDS:
        for shape in SHAPES:
            b_p = ART / f"{arch}__{shape}__single.json"
            o_p = ART / f"{arch}__{shape}__single__opt.json"
            if not (b_p.exists() and o_p.exists()):
                continue
            b = json.loads(b_p.read_text())
            o = json.loads(o_p.read_text())
            if b["status"] != "ok" or o["status"] != "ok":
                continue

            def dom(r):
                return max(r["derived_flops_per_partition"] / 197e12,
                           r["derived_bytes_per_partition"] / 819e9,
                           r["derived_coll_per_partition"] / 50e9)

            assert dom(o) <= dom(b) * 1.05, (arch, shape, dom(b), dom(o))
