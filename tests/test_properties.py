"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

# Optional dep: a missing hypothesis degrades this module to a skip instead
# of aborting the whole suite's collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Problem, evaluate, rate_matrix, solve_ould,
                        to_stages)
from repro.core.profiles import LayerProfile, ModelProfile
from repro.core.radio import RadioParams, sinr_matrix
from repro.optim import compression as comp


def _profile(mems, comps, outs):
    layers = tuple(LayerProfile(f"l{j}", m, c, o)
                   for j, (m, c, o) in enumerate(zip(mems, comps, outs)))
    return ModelProfile("prop", layers, input_bytes=max(outs) * 2)


@st.composite
def problems(draw):
    n = draw(st.integers(2, 5))
    m = draw(st.integers(2, 5))
    r = draw(st.integers(1, 3))
    mems = draw(st.lists(st.floats(1.0, 20.0), min_size=m, max_size=m))
    outs = draw(st.lists(st.floats(0.5, 32.0), min_size=m, max_size=m))
    cap = draw(st.floats(30.0, 200.0))
    seed = draw(st.integers(0, 100))
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 120, (n, 3))
    pos[:, 2] = 50.0
    prob = Problem(_profile(mems, [1.0] * m, outs), np.full(n, cap),
                   np.full(n, 1e9), rate_matrix(pos),
                   rng.integers(0, n, r).astype(np.int64))
    return prob


@settings(max_examples=25, deadline=None)
@given(problems())
def test_dp_solution_always_feasible(prob):
    sol = solve_ould(prob, solver="dp")
    ev = evaluate(prob, sol)
    assert ev.feasible
    # objective consistency: evaluator agrees with the solver's objective
    if sol.n_admitted == prob.n_requests:
        assert abs(ev.comm_latency_s - sol.objective) <= 1e-6 * max(
            1.0, abs(sol.objective))


@settings(max_examples=15, deadline=None)
@given(problems())
def test_ilp_not_worse_than_dp(prob):
    ilp = solve_ould(prob, mip_rel_gap=1e-6)
    dp = solve_ould(prob, solver="dp")
    if ilp.n_admitted == dp.n_admitted == prob.n_requests:
        assert ilp.objective <= dp.objective + 1e-6


@settings(max_examples=25, deadline=None)
@given(problems())
def test_stage_decomposition_roundtrip(prob):
    sol = solve_ould(prob, solver="dp")
    for r in range(prob.n_requests):
        if not sol.admitted[r]:
            continue
        stages = to_stages(sol.assign[r])
        # stages are contiguous, ordered, and cover all layers exactly once
        assert stages[0].layer_start == 0
        assert stages[-1].layer_end == prob.n_layers
        for a, b in zip(stages, stages[1:]):
            assert a.layer_end == b.layer_start
            assert a.node != b.node


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(0, 1000))
def test_sinr_symmetric_positive(n, seed):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 200, (n, 3))
    s = sinr_matrix(pos, RadioParams())
    assert (s >= 0).all()
    assert np.allclose(np.diag(s), 0.0)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3), min_size=1, max_size=64),
       st.integers(0, 5))
def test_compression_error_feedback_bounded(vals, rounds):
    """EF invariants: deq + e_new == g + e_prev exactly, and the residual is
    bounded by one quant step of the round's target."""
    g = {"w": np.asarray(vals, np.float32)}
    e = comp.init_error(g)
    for _ in range(rounds + 1):
        target = np.asarray(g["w"]) + np.asarray(e["w"])
        deq, e = comp.compress_with_feedback(g, e)
        np.testing.assert_allclose(
            np.asarray(deq["w"]) + np.asarray(e["w"]), target,
            rtol=1e-5, atol=1e-4)
        step = max(np.abs(target).max() / 127.0, 1e-9)
        assert np.abs(np.asarray(e["w"])).max() <= step + 1e-6
