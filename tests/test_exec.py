"""Plan-faithful execution engine (repro.exec): numeric equivalence to the
sequential reference across uniform and non-uniform cuts, stage dedup,
transfer pricing consistency, and measured-latency calibration."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (Problem, SnapshotView, Solution, get_planner,
                        lenet_profile, vgg16_profile)
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.planner import Plan
from repro.core.radio import RadioParams, rate_matrix
from repro.exec import (ExecutionEngine, calibrated_problem, coalesce_graphs,
                        compile_plan, layer_fns_for)
from repro.exec.stage_graph import stage_signature

MB = 1e6
TOL = 1e-5

# Non-uniform 2/3/4-stage cuts per model (stage layer counts, each sums to M).
CUTS = {
    "lenet": ([3, 4], [1, 4, 2], [2, 2, 1, 2]),        # M = 7 units
    "vgg16": ([5, 13], [2, 9, 7], [1, 6, 4, 7]),       # M = 18 units
}


def _uniform_problem(profile, n_nodes=6, requests=2, seed=0):
    mob = RPGMobility(RPGParams(n_uavs=n_nodes, area_m=120.0,
                                homogeneous=False), seed=seed)
    rates = rate_matrix(mob.positions(1, seed=seed)[0], RadioParams())
    sources = np.zeros(requests, np.int64)
    return Problem(profile, np.full(n_nodes, 4096 * MB),
                   np.full(n_nodes, 1e18), rates, sources,
                   compute_speed=np.full(n_nodes, 9.5e9))


def _manual_plan(prob, sizes_per_request):
    """A hand-built plan: request r runs stage s's layers on node s (so every
    cut point crosses a link)."""
    M = prob.n_layers
    R = len(sizes_per_request)
    assign = np.zeros((R, M), np.int64)
    for r, sizes in enumerate(sizes_per_request):
        assert sum(sizes) == M
        j = 0
        for node, size in enumerate(sizes):
            assign[r, j:j + size] = node
            j += size
    sol = Solution(assign, 0.0, "feasible", 0.0, np.ones(R, bool),
                   solver="manual")
    return Plan(sol, "manual", "snapshot", prob)


def _frames(rng, n, hw):
    return rng.standard_normal((n, *hw)).astype(np.float32)


@pytest.mark.parametrize("model,hw", [("lenet", (326, 595, 3)),
                                      ("vgg16", (48, 64, 3))])
def test_engine_matches_sequential_across_cuts(model, hw):
    """Executed output == sequential apply_layers for 2/3/4-stage
    non-uniform cuts (the satellite acceptance matrix)."""
    profile = (lenet_profile() if model == "lenet" else vgg16_profile())
    prob = _uniform_problem(profile)
    fns = layer_fns_for(profile, key=jax.random.PRNGKey(1))
    engine = ExecutionEngine(fns)
    rng = np.random.default_rng(0)
    for sizes in CUTS[model]:
        plan = _manual_plan(prob, [sizes, sizes])
        graph = compile_plan(plan)
        assert len(graph.tasks) == len(sizes)          # both requests batch
        assert graph.n_shared == len(sizes)            # dedup across requests
        frames = _frames(rng, 2, hw)
        report = engine.run(graph, frames)
        ref = engine.sequential_reference(frames, graph.requests)
        for r in graph.requests:
            err = np.abs(report.outputs[r] - ref[r]).max()
            assert err < TOL, (model, sizes, r, err)
        # every cut point shipped one boundary activation per request
        assert len(graph.transfers) == 2 * (len(sizes) - 1)


def test_engine_mixed_cuts_one_graph():
    """Requests with DIFFERENT cuts in one graph stay independent and
    correct (no cross-request batching of unequal stages)."""
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=3)
    fns = layer_fns_for(profile, key=jax.random.PRNGKey(2))
    engine = ExecutionEngine(fns)
    rng = np.random.default_rng(1)
    plan = _manual_plan(prob, [[3, 4], [1, 4, 2], [7]])
    graph = compile_plan(plan)
    frames = _frames(rng, 3, (326, 595, 3))
    report = engine.run(graph, frames)
    ref = engine.sequential_reference(frames, graph.requests)
    for r in graph.requests:
        assert np.abs(report.outputs[r] - ref[r]).max() < TOL
    sig = stage_signature(graph)
    assert (0, 7) in sig and (0, 3) in sig and (0, 1) in sig


def test_planner_plans_execute_equivalently():
    """The acceptance matrix: every plan a registered planner emits on a
    fixed-seed scenario executes numerically equivalent to sequential."""
    profile = lenet_profile()
    mob = RPGMobility(RPGParams(n_uavs=8, area_m=150.0, homogeneous=False),
                      seed=0)
    rates = rate_matrix(mob.positions(1)[0], RadioParams())
    rng = np.random.default_rng(0)
    sources = rng.integers(0, 3, 5).astype(np.int64)
    prob = Problem(profile, np.full(8, 128 * MB), np.full(8, 95e9), rates,
                   sources, compute_speed=np.full(8, 9.5e9))
    fns = layer_fns_for(profile, key=jax.random.PRNGKey(0))
    engine = ExecutionEngine(fns)
    frames = _frames(rng, 5, (326, 595, 3))
    for name in ("ould-dp", "ould-dp-sparse", "nearest", "hrm"):
        plan = get_planner(name).plan(prob, SnapshotView(rates))
        assert plan.n_admitted > 0, name
        graph = compile_plan(plan)
        report = engine.run(graph, frames)
        ref = engine.sequential_reference(frames, graph.requests)
        for r in graph.requests:
            err = np.abs(report.outputs[r] - ref[r]).max()
            assert err < TOL, (name, r, err)


def test_transfer_delays_match_paper_objective():
    """Graph transfer pricing sums to the evaluation's comm latency — the
    executed decomposition uses the exact coefficients OULD minimized."""
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=2)
    plan = _manual_plan(prob, [[3, 4], [2, 2, 1, 2]])
    graph = compile_plan(plan)
    ev = plan.evaluate()
    total = sum(tr.delay_s for tr in graph.transfers)
    assert total == pytest.approx(ev.comm_latency_s, rel=1e-9)
    for r in graph.requests:
        assert graph.transfer_delay_s(r) >= 0.0


def test_topological_task_order():
    """Every transfer's producer stage precedes its consumer stage."""
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=2)
    plan = _manual_plan(prob, [[1, 4, 2], [3, 4]])
    graph = compile_plan(plan)
    pos = {t.key: i for i, t in enumerate(graph.tasks)}
    for tr in graph.transfers:
        producer = max(i for k, i in pos.items()
                       if k[0] == tr.src_node and k[2] == tr.layer)
        consumer = min(i for k, i in pos.items()
                       if k[0] == tr.dst_node and k[1] == tr.layer)
        assert producer < consumer


def test_coalesce_graphs_batches_across_arrival_rounds():
    """Three admission rounds of the same hotspot cut collapse to one
    launch per stage; request ids shift by the round offsets."""
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=2)
    graphs = [compile_plan(_manual_plan(prob, [[3, 4], [3, 4]]))
              for _ in range(3)]
    merged = coalesce_graphs(graphs)
    assert merged.n_requests == 6
    assert merged.requests == (0, 1, 2, 3, 4, 5)
    # same stages as one round — six requests ride two launches
    assert len(merged.tasks) == 2
    assert all(t.requests == (0, 1, 2, 3, 4, 5) for t in merged.tasks)
    assert sum(len(g.tasks) for g in graphs) == 6      # 3× launch reduction
    # transfers carried over verbatim, re-identified
    assert len(merged.transfers) == 3 * len(graphs[0].transfers)
    base = {(tr.src_node, tr.dst_node, tr.layer, tr.nbytes, tr.delay_s)
            for tr in graphs[0].transfers}
    for tr in merged.transfers:
        assert (tr.src_node, tr.dst_node, tr.layer, tr.nbytes,
                tr.delay_s) in base


def test_coalesce_graphs_execution_equivalent():
    """Batched-across-arrival execution matches per-round execution on the
    same frames (the tentpole's exactness criterion)."""
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=2)
    fns = layer_fns_for(profile, key=jax.random.PRNGKey(3))
    engine = ExecutionEngine(fns)
    rng = np.random.default_rng(7)
    rounds = [compile_plan(_manual_plan(prob, [[3, 4], [1, 4, 2]]))
              for _ in range(2)]
    frames = _frames(rng, 4, (326, 595, 3))
    merged = coalesce_graphs(rounds)
    got = engine.run(merged, frames)
    for i, g in enumerate(rounds):
        solo = engine.run(g, frames[2 * i: 2 * i + 2])
        for r in g.requests:
            err = np.abs(got.outputs[r + 2 * i] - solo.outputs[r]).max()
            assert err < TOL, (i, r, err)
        # link pricing identical: coalescing never reroutes a transfer
        for r in g.requests:
            assert got.comm_s[r + 2 * i] == pytest.approx(solo.comm_s[r])
    # fewer launches than the per-round executions combined
    assert len(merged.tasks) < sum(len(g.tasks) for g in rounds)


def test_coalesce_graphs_rejects_model_mismatch():
    lenet = compile_plan(_manual_plan(_uniform_problem(lenet_profile()),
                                      [[3, 4], [3, 4]]))
    vgg = compile_plan(_manual_plan(_uniform_problem(vgg16_profile()),
                                    [[5, 13], [5, 13]]))
    with pytest.raises(ValueError, match="n_layers"):
        coalesce_graphs([lenet, vgg])
    with pytest.raises(ValueError, match="at least one"):
        coalesce_graphs([])
    with pytest.raises(ValueError, match="offsets"):
        coalesce_graphs([lenet], offsets=[0, 2])


def test_calibration_reduces_resolve_mae():
    """The acceptance gate: calibrated profiles cut the predicted-vs-
    measured MAE on a re-solve (analytic FLOP-model error ≫ timing noise)."""
    profile = lenet_profile()
    mob = RPGMobility(RPGParams(n_uavs=8, area_m=150.0, homogeneous=False),
                      seed=0)
    rates = rate_matrix(mob.positions(1)[0], RadioParams())
    rng = np.random.default_rng(0)
    sources = rng.integers(0, 3, 4).astype(np.int64)
    prob = Problem(profile, np.full(8, 128 * MB), np.full(8, 95e9), rates,
                   sources, compute_speed=np.full(8, 9.5e9))
    engine = ExecutionEngine(layer_fns_for(profile, key=jax.random.PRNGKey(0)))
    frames = _frames(rng, 4, (326, 595, 3))
    planner = get_planner("ould-dp")

    plan = planner.plan(prob, SnapshotView(rates))
    graph = compile_plan(plan)
    report = engine.run(graph, frames,
                        predicted_s=np.asarray(plan.evaluate().per_request_s))
    mae_before = report.abs_error_s[list(report.outputs)].mean()

    cal_prob, recon = calibrated_problem(prob, report)
    assert recon.layer_covered.any()
    assert recon.profile.num_layers == profile.num_layers
    # memory/output vectors untouched — calibration only updates compute
    assert recon.profile.memory_vector() == profile.memory_vector()
    assert recon.profile.output_vector() == profile.output_vector()

    replan = planner.plan(cal_prob, SnapshotView(rates))
    regraph = compile_plan(replan)
    rereport = engine.run(
        regraph, frames,
        predicted_s=np.asarray(replan.evaluate().per_request_s))
    mae_after = rereport.abs_error_s[list(rereport.outputs)].mean()
    assert mae_after < mae_before, (mae_before, mae_after)


def test_rejected_requests_never_compiled():
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=2)
    assign = np.zeros((2, profile.num_layers), np.int64)
    assign[1] = -1
    sol = Solution(assign, 0.0, "rejected:1", 0.0,
                   np.array([True, False]), solver="manual")
    plan = Plan(sol, "manual", "snapshot", prob)
    graph = compile_plan(plan)
    assert graph.requests == (0,)
    assert all(1 not in t.requests for t in graph.tasks)


def test_calibrated_problem_is_new_instance():
    """Calibration never mutates the analytic profile in place."""
    profile = lenet_profile()
    prob = _uniform_problem(profile, requests=1)
    engine = ExecutionEngine(layer_fns_for(profile, key=jax.random.PRNGKey(0)))
    plan = _manual_plan(prob, [[3, 4]])
    report = engine.run(compile_plan(plan),
                        _frames(np.random.default_rng(0), 1, (326, 595, 3)))
    before = list(profile.compute_vector())
    cal_prob, _ = calibrated_problem(prob, report)
    assert profile.compute_vector() == before
    assert cal_prob.profile is not profile
    assert dataclasses.is_dataclass(cal_prob.profile)
