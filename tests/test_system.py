"""End-to-end behaviour: the paper's scenario executed for real (placed CNN
inference over a simulated swarm) and placement↔sharding integration."""

import jax
import numpy as np

from repro.core import (Problem, evaluate, lenet_profile, solve_ould,
                        to_stages)
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.placement import balanced_stages, ould_pipeline_stages
from repro.core.profiles import lm_profile
from repro.core.radio import RadioParams, TpuLinkModel, rate_matrix
from repro.models import cnn

MB = 1e6


def _swarm_problem(requests=6, mem_mb=128):
    mob = RPGMobility(RPGParams(n_uavs=8, area_m=120.0), seed=0)
    pos = mob.positions(1)[0]
    rng = np.random.default_rng(0)
    return Problem(lenet_profile(), np.full(8, mem_mb * MB),
                   np.full(8, 95e9), rate_matrix(pos, RadioParams()),
                   rng.integers(0, 2, requests).astype(np.int64),
                   compute_speed=np.full(8, 9.5e9))


def test_placed_inference_equals_local_inference():
    """Distributing layers across nodes must not change the prediction —
    the paper's central accuracy-preservation claim, checked end-to-end."""
    prob = _swarm_problem()
    sol = solve_ould(prob, solver="dp")
    params = cnn.lenet_init(jax.random.PRNGKey(0))
    fns = cnn.lenet_layers(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 326, 595, 3))
    local = cnn.apply_layers(fns, x)
    for r in range(prob.n_requests):
        if not sol.admitted[r]:
            continue
        stages = to_stages(sol.assign[r])
        y = x
        for st in stages:
            y = cnn.apply_layers(fns, y, st.layer_start, st.layer_end)
        np.testing.assert_allclose(np.asarray(y), np.asarray(local),
                                   rtol=1e-5, atol=1e-5)


def test_distribution_kicks_in_under_memory_pressure():
    prob = _swarm_problem(requests=8, mem_mb=128)
    sol = solve_ould(prob, solver="dp")
    ev = evaluate(prob, sol)
    assert ev.feasible
    assert ev.shared_bytes > 0  # hotspot sources must offload something


def test_ould_pipeline_stages_cover_model():
    prof = lm_profile("toy", n_layers=12, d_model=512, n_heads=8, n_kv=8,
                      d_ff=1024, vocab=32000, seq=256)
    stages = ould_pipeline_stages(prof, n_groups=8,
                                  hbm_bytes_per_group=prof.total_memory / 3,
                                  flops_cap_per_group=1e18)
    assert stages[0].layer_start == 0
    assert stages[-1].layer_end == prof.num_layers
    assert len(stages) >= 3  # memory cap forces a real pipeline


def test_tpu_link_model_prefers_neighbors():
    link = TpuLinkModel()
    coords = np.array([[0, 0], [1, 0], [8, 0]])
    pods = np.zeros(3, np.int64)
    r = link.rate_matrix(coords, pods)
    assert r[0, 1] > r[0, 2]             # 1 hop beats 8 hops
    r2 = link.rate_matrix(coords, np.array([0, 1, 0]))
    assert r2[0, 1] == link.dcn_bytes_per_s  # cross-pod rides DCN


def test_balanced_stages_flops_balance():
    prof = lm_profile("toy", n_layers=16, d_model=256, n_heads=4, n_kv=4,
                      d_ff=512, vocab=1000, seq=128)
    stages = balanced_stages(prof, 4)
    flops = prof.compute_vector()
    per_stage = [sum(flops[s.layer_start:s.layer_end]) for s in stages]
    assert len(stages) == 4
    assert max(per_stage) / max(min(per_stage), 1.0) < 3.0
