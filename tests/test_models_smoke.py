"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU; output shapes + finiteness; decode
continuation equals the full-sequence forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import decode_step, forward, init_params, prefill
from repro.runtime import TrainConfig, init_opt_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, seed=0):
    k = jax.random.PRNGKey(seed)
    if cfg.embed_stub:
        return {"embeds": jax.random.normal(k, (B, S, cfg.d_model)) * 0.3,
                "labels": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = C.get_config(arch).reduced()
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = C.get_config(arch).reduced()
    params = init_params(KEY, cfg)
    tcfg = TrainConfig(remat=False)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params, tcfg)
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params must actually change
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = C.get_config(arch).reduced()
    params = init_params(KEY, cfg)
    T = 24
    if cfg.embed_stub:
        emb = jax.random.normal(KEY, (1, T, cfg.d_model)) * 0.3
        full, _ = forward(params, cfg, {"embeds": emb})
        lp, _ = prefill(params, cfg, {"embeds": emb[:, :20]}, max_len=T)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, 19]),
                                   rtol=3e-4, atol=3e-4)
        return
    toks = jax.random.randint(KEY, (1, T), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks})
    lp, cache = prefill(params, cfg, {"tokens": toks[:, :20]}, max_len=T)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, 19]),
                               rtol=3e-4, atol=3e-4)
    pos = jnp.int32(20)
    for i in range(20, T):
        lg, cache = decode_step(params, cfg, toks[:, i:i + 1], cache, pos)
        pos = pos + 1
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                   rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_long_context():
    """Sequence longer than the window: ring-buffer decode must equal the
    full forward (danube's long_500k mechanism at test scale)."""
    cfg = C.get_config("h2o_danube3_4b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, window=16)
    params = init_params(KEY, cfg)
    T = 48
    toks = jax.random.randint(KEY, (1, T), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks})
    lp, cache = prefill(params, cfg, {"tokens": toks[:, :40]}, max_len=T)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, 39]),
                               rtol=3e-4, atol=3e-4)
    pos = jnp.int32(40)
    for i in range(40, T):
        lg, cache = decode_step(params, cfg, toks[:, i:i + 1], cache, pos)
        pos = pos + 1
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                   rtol=3e-3, atol=3e-3)


def test_moe_scatter_matches_einsum_when_no_drops():
    """With generous capacity the scatter path must equal the dense path."""
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = C.get_config("granite_moe_3b").reduced()
    cfg_sc = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="scatter",
                                     capacity_factor=4.0))
    p = moe_mod.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.5
    y_e, aux_e = moe_mod.moe_apply(p, cfg, x)
    y_s, aux_s = moe_mod.moe_apply(p, cfg_sc, x)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_cnn_lenet_vgg_forward():
    from repro.models import cnn
    x = jax.random.normal(KEY, (1, 326, 595, 3))
    lp = cnn.lenet_init(KEY)
    out = cnn.apply_layers(cnn.lenet_layers(lp), x)
    assert out.shape == (1, 10) and np.isfinite(np.asarray(out)).all()
    # VGG on a reduced frame for CPU speed
    xs = jax.random.normal(KEY, (1, 64, 64, 3))
    vp = cnn.vgg16_init(KEY)
    out = cnn.apply_layers(cnn.vgg16_layers(vp), xs)
    assert out.shape == (1, 10) and np.isfinite(np.asarray(out)).all()
    # split execution == whole execution (placement primitive)
    mid = cnn.apply_layers(cnn.vgg16_layers(vp), xs, 0, 9)
    out2 = cnn.apply_layers(cnn.vgg16_layers(vp), mid, 9)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5,
                               atol=1e-5)
