"""Serving example: OULD-scheduled multi-request serving + real decode.

1. OULD places 6 concurrent serving requests' layer groups over a 16-group
   pod (ICI hop-rate topology) — the paper's multi-request placement driving
   the serving runtime.
2. A reduced internlm2 model then actually serves batched greedy generation
   (prefill → decode with donated KV caches).
3. A straggler appears: elastic.replan_placement re-solves OULD with the
   degraded group's capacity and shows the placement routing around it.

    PYTHONPATH=src python examples/serve_pipeline.py
"""

import jax
import numpy as np

import repro.configs as C
from repro.core.placement import to_stages
from repro.core.profiles import lm_profile
from repro.core.radio import TpuLinkModel
from repro.models import init_params
from repro.runtime import elastic
from repro.runtime.serve import ServeConfig, Server, schedule_requests


def main() -> None:
    full = C.get_config("internlm2_1p8b")
    link = TpuLinkModel()
    coords = np.stack([np.arange(16) % 16, np.zeros(16, np.int64)], -1)
    rates = link.rate_matrix(coords, np.zeros(16, np.int64)) * 8.0

    sol, ev = schedule_requests(full, n_nodes=16, requests=6,
                                hbm_bytes=16e9, flops_budget=197e12 * 10,
                                rates_bits=rates, seq=2048)
    print(f"OULD serving placement: admitted {ev.n_admitted}/6, "
          f"comm latency {ev.comm_latency_s * 1e6:.1f}us total")
    for r in range(3):
        if not sol.admitted[r]:
            print(f"  request {r} rejected")
            continue
        route = "->".join(str(s.node) for s in to_stages(sol.assign[r]))
        print(f"  request {r} route: [{route}]")

    # real batched generation on the reduced model
    cfg = full.reduced(n_layers=2, d_model=64, vocab=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, ServeConfig(max_len=64, batch_size=4))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 16))
    out = server.generate(prompts.astype(np.int32), steps=8)
    print(f"generated tokens shape {out.shape}: {out[0].tolist()}")

    # straggler: group 5 runs 4x slow → OULD routes around it
    prof = lm_profile(full.name, n_layers=full.n_layers, d_model=full.d_model,
                      n_heads=full.n_heads, n_kv=full.n_kv, d_ff=full.d_ff,
                      vocab=full.vocab, seq=2048)
    slow = np.ones(16)
    slow[5] = 4.0
    stages = elastic.replan_placement(prof, n_groups=16, hbm_bytes=16e9,
                                      flops_budget=197e12 * 10, slowdown=slow)
    nodes = [s.node for s in stages]
    print(f"straggler-aware stages (group 5 degraded): nodes={nodes}, "
          f"avoids_straggler={5 not in nodes}")
    print("serve_pipeline OK")


if __name__ == "__main__":
    main()
