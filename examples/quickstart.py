"""Quickstart: train a tiny LM for 30 steps on CPU with the full stack
(data pipeline → model → sharded AdamW → checkpointing), then resume from
the checkpoint to show exact restart.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import repro.configs as C
from repro.data import DataConfig
from repro.runtime import TrainConfig, train_loop


def main() -> None:
    cfg = C.get_config("internlm2_1p8b").reduced(n_layers=2, d_model=64,
                                                 vocab=512)
    tcfg = TrainConfig()
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    with tempfile.TemporaryDirectory() as d:
        lcfg = train_loop.LoopConfig(total_steps=30, ckpt_every=10,
                                     ckpt_dir=d)
        out = train_loop.run(cfg, tcfg, lcfg, dcfg)
        print(f"trained {len(out['losses'])} steps: "
              f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
        assert out["losses"][-1] < out["losses"][0], "loss should fall"

        # resume: loop should pick up at step 30 and do nothing more
        lcfg2 = train_loop.LoopConfig(total_steps=30, ckpt_every=10,
                                      ckpt_dir=d)
        out2 = train_loop.run(cfg, tcfg, lcfg2, dcfg)
        print(f"resume check: {len(out2['losses'])} new steps (expect 0)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
