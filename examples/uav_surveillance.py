"""End-to-end paper scenario: a swarm of resource-constrained UAVs runs
distributed CNN inference on captured frames.

Pipeline (all real computation, simulated radio):
  1. RPG mobility places 10 UAVs over the target area; Eq.(1) rates derived
     from SINR/path-loss.
  2. Frames arrive at hotspot UAVs → OULD (the paper's ILP) places each
     request's LeNet layers across the swarm under 512 MB / 9.5 GFLOPS caps.
  3. Each request executes for real: the JAX LeNet runs layer ranges per
     stage; activations "transmitted" between UAVs are accounted against
     the link rates to produce the end-to-end latency the paper plots.
  4. OULD-MP re-plans once for the whole predicted horizon and the run
     repeats while the swarm moves.

    PYTHONPATH=src python examples/uav_surveillance.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Problem, evaluate, lenet_profile, solve_ould,
                        solve_ould_mp, to_stages)
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.radio import RadioParams, rate_matrix
from repro.models import cnn
from repro.runtime.swarm import SwarmScenario, simulate

MB = 1e6


def execute_placed(layer_fns, x, stages, spb, input_bytes, k_bytes):
    """Run the placed inference for real, accumulating simulated link time."""
    t_comm = 0.0
    prev_node = None
    for st in stages:
        if prev_node is not None and st.node != prev_node:
            t_comm += k_bytes[st.layer_start - 1] * spb[prev_node, st.node]
        x = cnn.apply_layers(layer_fns, x, st.layer_start, st.layer_end)
        prev_node = st.node
    return x, t_comm


def main() -> None:
    profile = lenet_profile()
    params = cnn.lenet_init(jax.random.PRNGKey(0))
    layer_fns = cnn.lenet_layers(params)

    mob = RPGMobility(RPGParams(n_uavs=10, area_m=150.0, homogeneous=False),
                      seed=0)
    pos = mob.positions(1)[0]
    rates = rate_matrix(pos, RadioParams())
    rng = np.random.default_rng(0)
    requests = 8
    sources = rng.integers(0, 3, requests).astype(np.int64)

    # 128 MB nodes: a whole LeNet (108 MB) + any second request cannot fit,
    # so high loads force per-layer splits — the paper's core mechanism.
    prob = Problem(profile, mem_cap=np.full(10, 128 * MB),
                   comp_cap=np.full(10, 95e9), rates=rates, sources=sources,
                   compute_speed=np.full(10, 9.5e9))
    sol = solve_ould(prob, mip_rel_gap=1e-4, time_limit=20.0)
    ev = evaluate(prob, sol)
    print(f"OULD: {sol.status}, admitted {ev.n_admitted}/{requests}, "
          f"avg latency {ev.avg_latency_per_request:.3f}s, "
          f"shared {ev.shared_bytes / MB:.1f} MB")

    spb = prob.transfer_cost()
    k_bytes = profile.output_vector()
    frames = rng.standard_normal((requests, 326, 595, 3)).astype(np.float32)
    for r in range(requests):
        if not sol.admitted[r]:
            continue
        stages = to_stages(sol.assign[r])
        logits, t_comm = execute_placed(layer_fns, jnp.asarray(frames[r:r+1]),
                                        stages, spb, profile.input_bytes,
                                        k_bytes)
        cls = int(jnp.argmax(logits[0]))
        route = "->".join(str(s.node) for s in stages)
        print(f"  request {r}: class={cls} route=[{route}] "
              f"comm={t_comm * 1e3:.2f}ms")

    # OULD-MP over a 5-step horizon while the swarm moves
    mp = solve_ould_mp(profile, np.full(10, 256 * MB), np.full(10, 95e9),
                       sources, mob, horizon=5,
                       compute_speed=np.full(10, 9.5e9),
                       mip_rel_gap=1e-3, time_limit=20.0)
    lat = [f"{e.avg_latency_per_request:.3f}" for e in mp.per_step]
    print(f"OULD-MP one-shot plan, per-step latency over horizon: {lat}")

    # Streaming scenario: Poisson request arrivals on a two-group swarm whose
    # inter-group links fade in and out of range, plus node churn — epoch
    # re-placement with warm-started incremental OULD re-solves.
    scn = SwarmScenario(arrival_rate_hz=0.3, duration_ticks=90,
                        mtbf_s=60.0, mttr_s=20.0)
    for policy in ("ould", "ould_mp", "nearest"):
        r = simulate(scn, policy, seed=0)
        print(f"swarm[{policy:8s}]: deadline_miss={r.deadline_miss_rate:.3f} "
              f"rejected={r.rejection_rate:.3f} "
              f"avg_latency={r.avg_latency_s:.3f}s "
              f"resolve_total={r.total_resolve_s * 1e3:.1f}ms")
    print("uav_surveillance OK")


if __name__ == "__main__":
    main()
