"""End-to-end paper scenario: a swarm of resource-constrained UAVs runs
distributed CNN inference on captured frames.

Pipeline (all real computation, simulated radio):
  1. RPG mobility places 10 UAVs over the target area; Eq.(1) rates derived
     from SINR/path-loss.
  2. Frames arrive at hotspot UAVs → the chosen placement planner (the
     paper's OULD ILP by default) places each request's LeNet layers across
     the swarm under 512 MB / 9.5 GFLOPS caps.
  3. Each request executes for real: the JAX LeNet runs layer ranges per
     stage; activations "transmitted" between UAVs are accounted against
     the link rates to produce the end-to-end latency the paper plots.
  4. The horizon strategy (ould-mp) re-plans once for the whole predicted
     horizon and the run repeats while the swarm moves.

Every strategy goes through the registry (`repro.core.get_planner`), and all
printed strategy labels come from `Plan.planner_name` — the output stays
truthful as planners are added.

    PYTHONPATH=src python examples/uav_surveillance.py
"""

import dataclasses

import jax
import numpy as np

from repro.core import (HorizonView, Problem, SnapshotView, get_planner,
                        lenet_profile)
from repro.core.mobility import RPGMobility, RPGParams
from repro.core.radio import RadioParams, rate_matrix
from repro.exec import ExecutionEngine, compile_plan
from repro.models import cnn
from repro.runtime.swarm import SwarmScenario, simulate

MB = 1e6


def fmt_stats(plan) -> str:
    """Human-readable Plan.solve_stats (never silently dropped)."""
    st = plan.solve_stats
    if st is None:
        return "solve_stats: n/a"
    if st.k:
        return (f"solve_stats: k={st.k} escalations={st.n_escalations} "
                f"dense_fallbacks={st.n_dense_fallback} "
                f"pruned={st.pruned_fraction:.1%}")
    return (f"solve_stats: kept={st.n_kept} replaced={st.n_replaced} "
            f"cold={st.cold}")


def main() -> None:
    profile = lenet_profile()
    params = cnn.lenet_init(jax.random.PRNGKey(0))
    layer_fns = cnn.lenet_layers(params)

    mob = RPGMobility(RPGParams(n_uavs=10, area_m=150.0, homogeneous=False),
                      seed=0)
    pos = mob.positions(1)[0]
    rates = rate_matrix(pos, RadioParams())
    rng = np.random.default_rng(0)
    requests = 8
    sources = rng.integers(0, 3, requests).astype(np.int64)

    # 128 MB nodes: a whole LeNet (108 MB) + any second request cannot fit,
    # so high loads force per-layer splits — the paper's core mechanism.
    prob = Problem(profile, mem_cap=np.full(10, 128 * MB),
                   comp_cap=np.full(10, 95e9), rates=rates, sources=sources,
                   compute_speed=np.full(10, 9.5e9))
    planner = get_planner("ould-ilp", mip_rel_gap=1e-4, time_limit=20.0)
    plan = planner.plan(prob, SnapshotView(rates))
    ev = plan.evaluate()
    print(f"{plan.planner_name}: {plan.status}, "
          f"admitted {ev.n_admitted}/{requests}, "
          f"avg latency {ev.avg_latency_per_request:.3f}s, "
          f"shared {ev.shared_bytes / MB:.1f} MB")

    # The sparse pruned-DP strategy on the same instance, with its solver
    # telemetry surfaced from Plan.solve_stats.
    sparse_plan = get_planner("ould-dp-sparse").plan(prob, SnapshotView(rates))
    print(f"{sparse_plan.planner_name}: {sparse_plan.status}, "
          f"admitted {sparse_plan.n_admitted}/{requests} — "
          f"{fmt_stats(sparse_plan)}")

    # Execute the placed plan for real through the exec engine (repro.exec):
    # shared stages batch across requests, each stage is one jitted
    # apply_layers closure, link delays come from the same transfer_cost
    # matrix OULD minimized.
    frames = rng.standard_normal((requests, 326, 595, 3)).astype(np.float32)
    graph = compile_plan(plan)
    engine = ExecutionEngine(layer_fns)
    report = engine.run(graph, frames, predicted_s=ev.per_request_s)
    for r in graph.requests:
        cls = int(np.argmax(report.outputs[r][0]))
        route = "->".join(str(s.node) for s in plan.stages(r))
        print(f"  request {r}: class={cls} route=[{route}] "
              f"comm={report.comm_s[r] * 1e3:.2f}ms "
              f"measured={report.executed_s[r] * 1e3:.1f}ms "
              f"predicted={ev.per_request_s[r] * 1e3:.1f}ms")

    # The horizon strategy over 5 predicted steps while the swarm moves:
    # one placement judged against each realized step's snapshot.
    horizon = 5
    pred = mob.predicted_rates(horizon)
    mp_prob = Problem(profile, np.full(10, 256 * MB), np.full(10, 95e9),
                      pred, sources, compute_speed=np.full(10, 9.5e9))
    mp_planner = get_planner("ould-mp", mip_rel_gap=1e-3, time_limit=20.0)
    mp_plan = mp_planner.plan(mp_prob, HorizonView(pred))
    lat = [f"{e.avg_latency_per_request:.3f}"
           for e in mp_plan.evaluate_per_step()]
    print(f"{mp_plan.planner_name} one-shot plan, per-step latency over "
          f"horizon: {lat}")

    # Streaming scenario: Poisson request arrivals on a two-group swarm whose
    # inter-group links fade in and out of range, plus node churn — every
    # policy is a registry name; 'incremental' is warm-started snapshot OULD.
    scn = SwarmScenario(arrival_rate_hz=0.3, duration_ticks=90,
                        mtbf_s=60.0, mttr_s=20.0)
    for policy in ("incremental", "ould-mp", "nearest"):
        r = simulate(scn, policy, seed=0)
        print(f"swarm[{r.policy:12s}]: "
              f"deadline_miss={r.deadline_miss_rate:.3f} "
              f"rejected={r.rejection_rate:.3f} "
              f"avg_latency={r.avg_latency_s:.3f}s "
              f"resolve_total={r.total_resolve_s * 1e3:.1f}ms")

    # The degraded-view axis: same policy, same event tape, but the planner
    # only ever sees a 10-tick-old snapshot (serving stays on realized rates).
    stale = simulate(dataclasses.replace(scn, view_degradation="stale:10"),
                     "incremental", seed=0)
    print(f"swarm[incremental stale:10]: "
          f"deadline_miss={stale.deadline_miss_rate:.3f} "
          f"rejected={stale.rejection_rate:.3f} "
          f"avg_latency={stale.avg_latency_s:.3f}s")

    # Byte-moving transport (DESIGN.md §7): run the placed CNN with every
    # boundary activation shipped through worker OS processes, then hand the
    # realized per-link bandwidth to calibrate_rates so the planner re-solves
    # on measured comm — provenance rides in Plan.problem.comm_source.
    from repro.exec import calibrated_problem
    from repro.transport import LoopbackTransport

    with LoopbackTransport(n_workers=2) as tp:
        lb_engine = ExecutionEngine(layer_fns, transport=tp)
        lb_report = lb_engine.run(
            graph, frames, predicted_s=np.asarray(ev.per_request_s))
        exact = all(np.array_equal(lb_report.outputs[r], report.outputs[r])
                    for r in graph.requests)
        cal_prob, recon = calibrated_problem(prob, lb_report, transport=tp)
        print(f"transport[loopback]: workers={sorted(set(tp.worker_pids))} "
              f"moved={tp.moved_bytes / 1e6:.1f}MB exact={exact}")
        print(f"  {recon.summary()}")
        replan = planner.plan(cal_prob, SnapshotView(cal_prob.rates))
        print(f"  re-solve priced comm from {replan.problem.comm_source!r}")
    print("uav_surveillance OK")


if __name__ == "__main__":
    main()
