"""End-to-end driver: train a ~100M-parameter decoder LM for a few hundred
steps with the production loop — checkpointing, restart-on-failure, and
straggler detection all active.

By default runs a fast 60-step CPU config; pass --full for the ~100M model
and 300 steps (minutes on CPU).

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""

import argparse
import dataclasses
import tempfile

import repro.configs as C
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    base = C.get_config("internlm2_1p8b")
    if args.full:
        # ~100M params: 12L × d768 (GQA 12H/4kv, ff 3072), 32k vocab
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=3072,
            vocab=32000, head_dim=64)
        steps = args.steps or 300
        batch, seq = 8, 512
    else:
        cfg = base.reduced(n_layers=4, d_model=128, n_heads=4, vocab=2048)
        steps = args.steps or 60
        batch, seq = 8, 128

    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=20,
                                             total_steps=steps))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    with tempfile.TemporaryDirectory() as d:
        lcfg = train_loop.LoopConfig(total_steps=steps, ckpt_every=50,
                                     ckpt_dir=d)
        # inject one failure at 40% of the run: exercises restart/resume
        fail_step = {int(steps * 0.4)}
        fired = []

        def fail_at(s):
            if s in fail_step and s not in fired:
                fired.append(s)
                return True
            return False

        out = train_loop.run_with_restarts(cfg, tcfg, lcfg, dcfg,
                                           fail_at=fail_at)
        n = sum(p.size for p in __import__("jax").tree.leaves(out["params"]))
        print(f"params={n / 1e6:.1f}M steps={out['last_step'] + 1} "
              f"restarts={out['restarts']} "
              f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
        assert out["restarts"] >= 1, "failure injection should have fired"
        assert out["losses"][-1] < out["losses"][0]
    print("train_100m OK")


if __name__ == "__main__":
    main()
